"""Elastic capacity pool: free-pool regrowth + evalsched GPU borrowing,
now with node-local revocable leases.

The pool unifies the paper's two §6 systems over one free-GPU ledger
(``repro.cluster.replay``): shrunken elastic jobs (§6.1) reclaim width from
the free pool at any capacity event instead of waiting ~a day for their
lender node's repair, and decomposed §6.2 eval trials lease the idle
fragments in between, preempted back the moment the cluster wants the GPUs.
This bench characterizes both sides at Seren scale (fast mode: Kalos 20k):

  * regrowth — with the pool ON, essentially every elastic shrink regrows
    (vs the repair-only world where most shrunken jobs *finish* before the
    node returns); reported as regrow events per shrink in both worlds,
    plus the explicit re-shard stall regrowth now pays;
  * placement — leases are node-local (``placement=True``): borrowed eval
    shards land on concrete ``SimulatedFleet`` nodes and their model loads
    share that node's 25 Gb/s storage NIC, so the Fig. 16 load collapse
    shows up inside the replay (``summary()["placement"]``);
  * best-effort tier — checkpointed low-priority jobs run on revocable
    leases over idle capacity (including the pretraining reservation) and
    are preempted back to their last checkpoint when dispatch or regrowth
    reclaims the lease: the §3.2 quota-reclamation preemption as policy;
  * borrowing — borrowed GPU-hours, lease/preemption counts and the share
    of otherwise-idle free capacity the trials soak up;
  * head-delay tail — the EASY shadow-estimate error figure: a conservative
    EASY scheduler promises the head a start time computed from running
    jobs' scheduled ends, but injected failures/repairs/regrowths it cannot
    foresee move the realized start; the p50/p95/p99 error is the paper's
    "how wrong is the estimate at scale" characterization;
  * throughput — a fixed interleaved-calibration probe over the EASY +
    borrower + placement + best-effort configuration yields
    ``events_per_calib``, gated by ``benchmarks.check_regression``
    alongside the replay/evalsched gates.

The four worlds (repair-only, pool, EASY, probe) replay deterministically
regenerated traces and are independent, so they run in parallel via
``benchmarks.common.run_worlds`` — the suite used to walk them
sequentially, which dominated its wall time. Each world keeps one warm
``DiagnosisLoop`` across its own replays (bounded verdict cache,
per-world; the engine's shared-loop delta accounting is regression-tested
in ``tests/test_replay.py``).
"""
from __future__ import annotations

import time

from benchmarks.common import Row, calibrated_probe, emit, run_worlds
from repro.cluster import (KALOS, SEREN, DiagnosisLoop, FailureInjector,
                           ReplayConfig, generate_jobs, replay_trace)
from repro.core.evalsched import STORAGE_SPEC, TrialBorrower

N_JOBS_FULL = 200_000            # Seren slice: saturated spare pool
N_JOBS_FAST = 20_000
N_JOBS_PROBE = 50_000            # fixed CI-gate throughput probe

BEST_EFFORT_FRAC = 0.3           # share of eligible jobs on revocable leases
RESHARD_COST_MIN = 1.0           # explicit regrow re-shard stall


def _borrower(*, repeat: int) -> TrialBorrower:
    return TrialBorrower.from_suite(63, repeat=repeat, spec=STORAGE_SPEC)


def _config(loop: DiagnosisLoop, *, regrow: bool = True, borrower=None,
            backfill=False, placement: bool = False) -> ReplayConfig:
    return ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                        diagnosis=loop, elastic=True,
                        opportunistic_regrow=regrow,
                        placement=placement,
                        reshard_cost_min=RESHARD_COST_MIN,
                        borrower=borrower, backfill=backfill)


def _jobs(fast: bool):
    spec = KALOS if fast else SEREN
    return spec, generate_jobs(spec, seed=0,
                               n_jobs=N_JOBS_FAST if fast else N_JOBS_FULL,
                               best_effort_frac=BEST_EFFORT_FRAC)


# -- parallel worlds (module-level: must pickle) ----------------------------

def _world_repair_only(fast: bool) -> dict:
    """PR-2 semantics: width returns only at the lender node's REPAIR."""
    spec, jobs = _jobs(fast)
    res = replay_trace(jobs, spec.n_gpus,
                       reserved_frac=0.97 if fast else 0.95,
                       config=_config(DiagnosisLoop(), regrow=False))
    return {"shrinks": res.elastic_shrinks, "regrows": res.elastic_regrows}


def _world_pool(fast: bool) -> dict:
    """Node-local placement + opportunistic regrowth + best-effort
    revocable leases + trial borrowing."""
    spec, jobs = _jobs(fast)
    loop = DiagnosisLoop()
    t0 = time.perf_counter()
    res = replay_trace(jobs, spec.n_gpus,
                       reserved_frac=0.97 if fast else 0.95,
                       config=_config(loop,
                                      borrower=_borrower(
                                          repeat=100 if fast else 500),
                                      placement=True))
    wall = time.perf_counter() - t0
    s = res.summary()
    return {"wall": wall, "shrinks": res.elastic_shrinks,
            "pool": s["pool"], "placement": s["placement"],
            "pipeline_runs": loop.pipeline_runs}


def _world_easy(fast: bool) -> dict:
    """EASY world: head-delay tail + shadow-estimate error (the figure)."""
    spec, jobs = _jobs(fast)
    loop = DiagnosisLoop()
    res = replay_trace(jobs, spec.n_gpus,
                       reserved_frac=0.97 if fast else 0.95,
                       config=_config(loop, backfill="easy"))
    return {"head_delay": res.summary()["head_delay"],
            "pipeline_runs": loop.pipeline_runs}


def _world_probe() -> float:
    """Fixed-shape calibrated throughput probe (EASY + borrower +
    placement + best-effort: the most machinery the engine can run at
    once); methodology in benchmarks.common.calibrated_probe, shared with
    the replay gates. One warm DiagnosisLoop across the rounds."""
    probe_jobs = generate_jobs(KALOS, seed=0, n_jobs=N_JOBS_PROBE,
                               best_effort_frac=BEST_EFFORT_FRAC)
    loop = DiagnosisLoop()
    return calibrated_probe(
        lambda: replay_trace(
            probe_jobs, KALOS.n_gpus, reserved_frac=0.97,
            config=_config(loop,
                           borrower=_borrower(repeat=50),
                           backfill="easy",
                           placement=True)).events_processed)


def run(fast: bool = False) -> list[Row]:
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    out = run_worlds({
        "off": (_world_repair_only, (fast,)),
        "on": (_world_pool, (fast,)),
        "easy": (_world_easy, (fast,)),
        "probe": (_world_probe,),
    })
    off, on, easy = out["off"], out["on"], out["easy"]
    events_per_calib = out["probe"]

    off_ratio = off["regrows"] / max(off["shrinks"], 1)
    pool = on["pool"]
    placement = on["placement"]
    be = pool["best_effort"]
    on_ratio = (pool["regrowth"]["pool_regrows"]
                + pool["regrowth"]["repair_regrows"]) \
        / max(on["shrinks"], 1)
    borrow = pool["borrow"]
    hd = easy["head_delay"]
    err = hd["shadow_error"]
    runs_max = max(on["pipeline_runs"], easy["pipeline_runs"])

    return [
        Row("pool", "n_jobs", float(n_jobs), "", "", None),
        Row("pool", "replay_wall_s", on["wall"], "", "s"),
        Row("pool", "events_per_calib", events_per_calib,
            "CI regression gate (calibrated)", ""),
        # -- regrowth: pool vs repair-only ----------------------------------
        Row("pool", "elastic_shrinks", float(on["shrinks"]),
            "hardware-verdict wide jobs shrank", "",
            on["shrinks"] > 0),
        Row("pool", "pool_regrows", float(pool["regrowth"]["pool_regrows"]),
            "width reclaimed from the free pool", "",
            pool["regrowth"]["pool_regrows"] > 0),
        Row("pool", "regrows_per_shrink", on_ratio,
            "~every shrink regrows with the pool", "",
            # a 20k fast trace is mostly idle — shrunken jobs often finish
            # before any capacity event lands; assert at full scale
            None if fast else on_ratio >= 0.5),
        Row("pool", "regrows_per_shrink_repair_only", off_ratio,
            "repair-only world: most jobs finish shrunken", "",
            on_ratio > off_ratio),
        Row("pool", "pool_regrown_gpus",
            float(pool["regrowth"]["pool_regrown_gpus"]), "", ""),
        Row("pool", "reshard_stall_min",
            pool["regrowth"]["reshard_stall_min"],
            "explicit regrow re-shard cost", "min",
            pool["regrowth"]["reshard_stall_min"] > 0
            if pool["regrowth"]["events"] else None),
        # -- node-local placement (Fig. 16 collapse in the replay) ----------
        Row("pool", "placement_nodes", float(placement.get("n_nodes", 0)),
            "leases tied to SimulatedFleet nodes", "",
            placement.get("n_nodes", 0) > 0),
        Row("pool", "borrow_load_max_concurrency",
            float(placement.get("max_load_concurrency", 0)),
            "loads sharing one node NIC", "",
            None if fast else placement.get("max_load_concurrency", 0) >= 2),
        Row("pool", "borrow_load_collapse_x",
            placement.get("load_collapse_x", 0.0),
            "Fig. 16: load slows when sharing the NIC", "",
            None if fast else placement.get("load_collapse_x", 0.0) > 1.0),
        # -- best-effort revocable leases (§3.2 quota reclamation) ----------
        Row("pool", "best_effort_jobs", float(be["jobs"]),
            "checkpointed jobs on revocable leases", "", be["jobs"] > 0),
        Row("pool", "best_effort_lease_starts", float(be["lease_starts"]),
            "", "", be["lease_starts"] > 0),
        Row("pool", "best_effort_revocations", float(be["revocations"]),
            "quota reclaimed by dispatch/regrowth", "",
            None if fast else be["revocations"] > 0),
        Row("pool", "best_effort_lost_gpu_hours", be["lost_gpu_hours"],
            "rolled back to the last checkpoint", "GPUh"),
        # -- borrowing ------------------------------------------------------
        Row("pool", "borrowed_gpu_hours", borrow["borrowed_gpu_hours"],
            "trials ran on leased free-pool GPUs", "GPUh",
            borrow["borrowed_gpu_hours"] > 0),
        Row("pool", "borrow_leases", float(borrow["leases"]), "", ""),
        Row("pool", "borrow_preemptions", float(borrow["preemptions"]),
            "revoked by dispatch/regrowth", ""),
        Row("pool", "borrow_shards_completed",
            float(borrow["shards_completed"]), "", "",
            borrow["shards_completed"] > 0),
        Row("pool", "borrow_restart_overhead_min",
            borrow["restart_overhead_min"],
            "decomposed-trial restart + NIC reload cost", "min"),
        # -- EASY head-delay tail (shadow-estimate error figure) ------------
        Row("pool", "easy_head_delay_p50_min", hd["p50_min"], "", "min",
            hd["n"] > 0),
        Row("pool", "easy_head_delay_p95_min", hd["p95_min"], "", "min"),
        Row("pool", "easy_head_delay_p99_min", hd["p99_min"],
            "blocked-head wait tail under EASY", "min"),
        Row("pool", "easy_shadow_error_p50_min", err["p50_min"],
            "EASY estimate is mostly exact", "min",
            abs(err["p50_min"]) < 1.0),
        Row("pool", "easy_shadow_error_p99_min", err["p99_min"],
            "tail = unforeseen failures/repairs", "min", err["n"] > 0),
        # -- per-world diagnosis loops --------------------------------------
        Row("pool", "diagnosis_pipeline_runs_total", float(runs_max),
            "per-world verdict cache stays bounded", "",
            0 < runs_max <= 3 * 32),
    ]


def main(fast: bool = False) -> None:
    emit(run(fast), "pool")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
