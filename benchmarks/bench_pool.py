"""Elastic capacity pool: free-pool regrowth + evalsched GPU borrowing.

The pool unifies the paper's two §6 systems over one free-GPU ledger
(``repro.cluster.replay``): shrunken elastic jobs (§6.1) reclaim width from
the free pool at any capacity event instead of waiting ~a day for their
lender node's repair, and decomposed §6.2 eval trials lease the idle
fragments in between, preempted back the moment the cluster wants the GPUs.
This bench characterizes both sides at Seren scale (fast mode: Kalos 20k):

  * regrowth — with the pool ON, essentially every elastic shrink regrows
    (vs the repair-only world where most shrunken jobs *finish* before the
    node returns); reported as regrow events per shrink in both worlds;
  * borrowing — borrowed GPU-hours, lease/preemption counts and the share
    of otherwise-idle free capacity the trials soak up;
  * head-delay tail — the EASY shadow-estimate error figure: a conservative
    EASY scheduler promises the head a start time computed from running
    jobs' scheduled ends, but injected failures/repairs/regrowths it cannot
    foresee move the realized start; the p50/p95/p99 error is the paper's
    "how wrong is the estimate at scale" characterization;
  * throughput — a fixed interleaved-calibration probe over the EASY +
    borrower + elastic configuration yields ``events_per_calib``, gated by
    ``benchmarks.check_regression`` alongside the replay/evalsched gates.
"""
from __future__ import annotations

import time

from benchmarks.common import Row, calibrated_probe, emit
from repro.cluster import (KALOS, SEREN, FailureInjector, ReplayConfig,
                           generate_jobs, replay_trace)
from repro.core.evalsched import TrialBorrower

N_JOBS_FULL = 200_000            # Seren slice: saturated spare pool
N_JOBS_FAST = 20_000
N_JOBS_PROBE = 50_000            # fixed CI-gate throughput probe


def _config(*, regrow: bool = True, borrower=None, backfill=False
            ) -> ReplayConfig:
    return ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                        diagnose=True, elastic=True,
                        opportunistic_regrow=regrow,
                        borrower=borrower, backfill=backfill)


def run(fast: bool = False) -> list[Row]:
    spec = KALOS if fast else SEREN
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    frac = 0.97 if fast else 0.95
    jobs = generate_jobs(spec, seed=0, n_jobs=n_jobs)

    # 1) repair-only world (PR-2 semantics): width returns only at REPAIR
    off = replay_trace(jobs, spec.n_gpus, reserved_frac=frac,
                       config=_config(regrow=False))
    off_shrinks = max(off.elastic_shrinks, 1)
    off_ratio = off.elastic_regrows / off_shrinks

    # 2) pool world: opportunistic regrowth + trial borrowing
    borrower = TrialBorrower.from_suite(63, repeat=100 if fast else 500)
    t0 = time.perf_counter()
    on = replay_trace(jobs, spec.n_gpus, reserved_frac=frac,
                      config=_config(borrower=borrower))
    wall = time.perf_counter() - t0
    pool = on.summary()["pool"]
    on_shrinks = max(on.elastic_shrinks, 1)
    on_ratio = (pool["regrowth"]["pool_regrows"]
                + pool["regrowth"]["repair_regrows"]) / on_shrinks
    borrow = pool["borrow"]

    # 3) EASY world: head-delay tail + shadow-estimate error (the figure)
    easy = replay_trace(jobs, spec.n_gpus, reserved_frac=frac,
                        config=_config(backfill="easy"))
    hd = easy.summary()["head_delay"]
    err = hd["shadow_error"]

    # 4) fixed-shape calibrated throughput probe (EASY + borrower + elastic:
    #    the most machinery the engine can run at once); methodology in
    #    benchmarks.common.calibrated_probe, shared with the replay gate
    probe_jobs = generate_jobs(KALOS, seed=0, n_jobs=N_JOBS_PROBE)
    events_per_calib = calibrated_probe(
        lambda: replay_trace(
            probe_jobs, KALOS.n_gpus, reserved_frac=0.97,
            config=_config(borrower=TrialBorrower.from_suite(63, repeat=50),
                           backfill="easy")).events_processed)

    return [
        Row("pool", "n_jobs", float(n_jobs), "", "", None),
        Row("pool", "replay_wall_s", wall, "", "s"),
        Row("pool", "events_per_calib", events_per_calib,
            "CI regression gate (calibrated)", ""),
        # -- regrowth: pool vs repair-only ----------------------------------
        Row("pool", "elastic_shrinks", float(on.elastic_shrinks),
            "hardware-verdict wide jobs shrank", "",
            on.elastic_shrinks > 0),
        Row("pool", "pool_regrows", float(pool["regrowth"]["pool_regrows"]),
            "width reclaimed from the free pool", "",
            pool["regrowth"]["pool_regrows"] > 0),
        Row("pool", "regrows_per_shrink", on_ratio,
            "~every shrink regrows with the pool", "",
            # a 20k fast trace is mostly idle — shrunken jobs often finish
            # before any capacity event lands; assert at full scale
            None if fast else on_ratio >= 0.5),
        Row("pool", "regrows_per_shrink_repair_only", off_ratio,
            "repair-only world: most jobs finish shrunken", "",
            on_ratio > off_ratio),
        Row("pool", "pool_regrown_gpus",
            float(pool["regrowth"]["pool_regrown_gpus"]), "", ""),
        # -- borrowing ------------------------------------------------------
        Row("pool", "borrowed_gpu_hours", borrow["borrowed_gpu_hours"],
            "trials ran on leased free-pool GPUs", "GPUh",
            borrow["borrowed_gpu_hours"] > 0),
        Row("pool", "borrow_leases", float(borrow["leases"]), "", ""),
        Row("pool", "borrow_preemptions", float(borrow["preemptions"]),
            "revoked by dispatch/regrowth", ""),
        Row("pool", "borrow_shards_completed",
            float(borrow["shards_completed"]), "", "",
            borrow["shards_completed"] > 0),
        Row("pool", "borrow_restart_overhead_min",
            borrow["restart_overhead_min"],
            "decomposed-trial restart cost", "min"),
        # -- EASY head-delay tail (shadow-estimate error figure) ------------
        Row("pool", "easy_head_delay_p50_min", hd["p50_min"], "", "min",
            hd["n"] > 0),
        Row("pool", "easy_head_delay_p95_min", hd["p95_min"], "", "min"),
        Row("pool", "easy_head_delay_p99_min", hd["p99_min"],
            "blocked-head wait tail under EASY", "min"),
        Row("pool", "easy_shadow_error_p50_min", err["p50_min"],
            "EASY estimate is mostly exact", "min",
            abs(err["p50_min"]) < 1.0),
        Row("pool", "easy_shadow_error_p99_min", err["p99_min"],
            "tail = unforeseen failures/repairs", "min", err["n"] > 0),
    ]


def main(fast: bool = False) -> None:
    emit(run(fast), "pool")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
