"""CI perf-regression gate over the benchmark artifacts.

Compares a freshly produced ``--fast`` benchmark run against the committed
baselines in ``artifacts/bench/`` and fails (exit 1) on a >25% throughput
regression in the gated benches:

  * ``replay``     — ``events_per_calib``: the fixed 100k-job injected
    replay probe's events/s divided by the interleaved same-window CPU
    calibration (``benchmarks.common.calibration_chunk``), so the number
    survives both a change of runner class and bursty CPU contention;
  * ``pool``       — same calibrated methodology over the elastic capacity
    pool configuration (EASY backfill + opportunistic regrowth + trial
    borrowing), gating the free-GPU-ledger machinery specifically;
  * ``evalsched``  — calibrated decoupled-scheduler throughput (repeated
    full §6.2 schedules, engine completions per calibrated op);
  * ``serve``      — ``events_per_calib`` / ``events_per_calib_serve``:
    the fixed 100k-request serving-replay probe (continuous batching +
    KV paging), hermetically priced so the gate is independent of the
    committed dryrun cell set;
  * ``detection``  — two-round sweep probe savings vs naive pairwise
    (deterministic, seeded: any drop is a real algorithmic regression);
  * ``checkpoint`` — sync/async stall-reduction ratios (a ratio of two
    same-machine timings, so machine speed cancels);
  * ``kernel_cost`` — the static per-kernel cost table
    (``repro.quality.pallas_cost``): deterministic predicted arithmetic
    intensity per (kernel, shape), the cost-model agreement bool, and the
    row count — a kernel edit that degrades predicted intensity fails
    here even though nothing was timed.

Usage (what ``.github/workflows/ci.yml`` runs after the fast bench step):

  REPRO_BENCH_DIR=artifacts/bench-fresh python -m benchmarks.run --fast
  python -m benchmarks.check_regression \
      --fresh artifacts/bench-fresh --baseline artifacts/bench

A metric missing from the baseline is reported and skipped (new benches
must not fail the gate retroactively); a metric missing from the fresh run
fails it (the bench should have produced it).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

# bench -> [(metric, direction, tolerance)]; direction "higher" = bigger is
# better; tolerance None = the run's --tolerance (default 25%). The
# checkpoint stall-reduction ratio pits a ~2 s sync save against a ~0.1 s
# async snapshot, and the small denominator swings up to ~2x under runner
# CPU contention even with min-of-3 sampling — so it gets a wider band
# that still catches the real failure mode (losing the async path
# collapses the ratio from ~15-25x to ~1x).
#
# Baseline-mode rule: every gated replay/pool/evalsched metric comes from a
# fixed probe that is identical in --fast and full runs, so those baselines
# may be committed from either mode. The checkpoint ratios are NOT
# shape-independent (full mode saves much larger checkpoints, inflating
# the ratio ~10x) — its committed baseline must come from a --fast run.
GATES: dict[str, list[tuple[str, str, Optional[float]]]] = {
    # events_per_calib is the historical aggregate gate (the full-feature
    # configuration); events_per_calib_full is the same measurement under
    # its per-knob name (PR 5's legacy/placement/best_effort/full feature
    # matrix) — gated so the per-knob row can never silently vanish or
    # regress while the aggregate survives on a renamed probe. A metric
    # missing from the *baseline* is skipped (new rows don't fail
    # retroactively), so committing a pre-PR-5 baseline stays green.
    "replay": [("events_per_calib", "higher", None),
               ("events_per_calib_full", "higher", None)],
    "pool": [("events_per_calib", "higher", None)],
    # the serving replay's probe prices hermetically (CostModel.analytic),
    # so the gate stays armed across dryrun cell-set changes even though
    # the bench's headline rows are dryrun-stamped; the _serve alias is
    # gated for the same can't-silently-vanish reason as the replay rows
    "serve": [("events_per_calib", "higher", None),
              ("events_per_calib_serve", "higher", None),
              # fault-injected probe: same hermetic pricing plus the §5
              # teardown/diagnosis/retry machinery in the measured loop
              ("events_per_calib_serve_faults", "higher", None)],
    # the fair-share engine's rate recomputation is dict/cache-bound while
    # the calibration chunk is heap-bound, so the ratio cancels contention
    # less cleanly than the replay probes (observed ~1.2-1.4x run-to-run
    # spread on a noisy box); the wider band still catches the real
    # failure mode (an O(n^2) regression in Engine.run tanks it outright)
    "evalsched": [("events_per_calib", "higher", 0.5)],
    "detection": [("n128_probe_savings", "higher", None),
                  ("n512_probe_savings", "higher", None)],
    "checkpoint": [("7B-analog_stall_reduction", "higher", 0.5),
                   ("123B-analog_stall_reduction", "higher", 0.5)],
    # cost-model benches (dryrun artifacts + analytic fallback). n_cells
    # gets a tight band (losing a cell from CI's 4-cell set is a real
    # artifact-pipeline regression); the physics ratios get wide bands —
    # they only move when the model or the calibration changes, and the
    # dryrun-provenance guard below already skips cross-cell-set compares.
    "roofline": [("n_cells", "higher", 0.2),
                 ("worst_roofline_frac", "higher", 0.5)],
    "moe_comm": [("deepseek_over_dense", "higher", 0.5),
                 ("mixtral_over_dense", "higher", 0.5),
                 ("deepseek_a2a_gib_per_step", "higher", 0.5)],
    # static kernel cost table (repro.quality.pallas_cost): fully
    # deterministic (no timing), so any movement is a real kernel
    # blocking/indexing change — a >25% intensity-envelope shrink must be
    # deliberate (recommit the baseline with the PR). The agreement bool
    # collapsing 1 -> 0 trips any band; hard failures (RPL2xx findings)
    # are additionally refused outright by the pallas_cost stamp check.
    "kernel_cost": [("cost_model_agreement", "higher", None),
                    ("n_rows", "higher", None),
                    ("min_intensity", "higher", None),
                    ("max_intensity", "higher", None)],
}

# benches whose rows derive from artifacts/dryrun/** cells: their metrics
# are only comparable when fresh and baseline were built from the *same*
# cell set, so the per-artifact ``dryrun_fingerprint`` stamp (see
# benchmarks.common.emit) must match before any metric is judged
DRYRUN_GUARDED = ("roofline", "moe_comm")

DEFAULT_TOLERANCE = 0.25


def _load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        return {r["metric"]: float(r["value"]) for r in json.load(f)}


def check_replint_stamps(fresh_dir: str) -> list[str]:
    """Refuse bench artifacts produced by a lint-dirty tree.

    ``benchmarks.run`` stamps every artifact with the tree's replint
    verdict (``replint_clean`` row, see ``benchmarks.common.emit``); a
    stamp saying the tree carried non-baseline findings fails the gate —
    numbers recorded while the determinism lint was red must never be
    compared, let alone become committed baselines. Unstamped artifacts
    (pre-replint baselines, direct bench-module runs) pass with a note."""
    failures: list[str] = []
    unstamped = 0
    for name in sorted(os.listdir(fresh_dir)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(fresh_dir, name)
        try:
            rows = _load_rows(path)
        except (ValueError, TypeError, KeyError):
            continue                 # not a bench row file (e.g. profile)
        clean = rows.get("replint_clean")
        if clean is None:
            unstamped += 1
        elif clean == 0.0:
            failures.append(
                f"{name}: produced by a tree with non-baseline replint "
                f"findings ({int(rows.get('replint_findings', -1))}); fix "
                "the lint findings and re-run the benches")
        if rows.get("pallas_cost_clean") == 0.0:
            failures.append(
                f"{name}: produced by a tree whose kernels carry RPL2xx "
                "resource findings or fail the cost-model cross-check "
                f"({int(rows.get('pallas_cost_findings', -1))} findings); "
                "fix the kernels and re-run the benches")
    if unstamped:
        print(f"  replint stamp: {unstamped} unstamped artifacts "
              "(pre-replint or direct module runs), tolerated")
    return failures


def check(fresh_dir: str, baseline_dir: str,
          tolerance: float = DEFAULT_TOLERANCE) -> list[str]:
    """Return the list of failure messages (empty = gate passes)."""
    print(f"perf-regression gate: fresh={fresh_dir} baseline={baseline_dir} "
          f"tolerance={tolerance:.0%}")
    failures: list[str] = check_replint_stamps(fresh_dir)
    for bench, metrics in GATES.items():
        fresh_path = os.path.join(fresh_dir, f"{bench}.json")
        base_path = os.path.join(baseline_dir, f"{bench}.json")
        if not os.path.exists(fresh_path):
            failures.append(f"{bench}: fresh artifact missing ({fresh_path})")
            continue
        if not os.path.exists(base_path):
            print(f"  {bench}: no committed baseline, skipped")
            continue
        fresh = _load_rows(fresh_path)
        base = _load_rows(base_path)
        if bench in DRYRUN_GUARDED:
            f_fp = fresh.get("dryrun_fingerprint")
            b_fp = base.get("dryrun_fingerprint")
            if f_fp is None or b_fp is None:
                print(f"  {bench}: unstamped dryrun provenance "
                      f"(fresh={f_fp} base={b_fp}), metrics skipped")
                continue
            if f_fp != b_fp:
                print(f"  {bench}: dryrun cell set differs from baseline "
                      f"(fingerprint {f_fp:.0f} vs {b_fp:.0f}) — rows are "
                      "not comparable, metrics skipped (recommit the "
                      "baseline to re-arm the gate)")
                continue
        for metric, direction, tol_override in metrics:
            tol = tolerance if tol_override is None else tol_override
            if metric not in fresh:
                failures.append(f"{bench}.{metric}: missing from fresh run")
                continue
            if metric not in base:
                print(f"  {bench}.{metric}: not in baseline, skipped")
                continue
            f_val, b_val = fresh[metric], base[metric]
            if b_val <= 0:
                print(f"  {bench}.{metric}: degenerate baseline "
                      f"({b_val:.4g}), skipped")
                continue
            if direction == "higher":
                ratio = f_val / b_val
            else:
                ratio = b_val / f_val if f_val > 0 else 0.0
            bad = ratio < 1.0 - tol
            verdict = "REGRESSION" if bad else "ok"
            print(f"  {bench}.{metric}: fresh={f_val:.4g} base={b_val:.4g} "
                  f"({ratio:.2f}x of baseline, tolerance {tol:.0%}) "
                  f"{verdict}")
            if bad:
                failures.append(
                    f"{bench}.{metric} regressed to {ratio:.2f}x of the "
                    f"baseline ({f_val:.4g} vs {b_val:.4g}, "
                    f"tolerance {tol:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default=os.environ.get(
        "REPRO_BENCH_DIR", "artifacts/bench-fresh"),
        help="directory with the freshly produced bench JSON")
    ap.add_argument("--baseline", default="artifacts/bench",
                    help="directory with the committed baselines")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional regression (default 0.25)")
    args = ap.parse_args(argv)
    failures = check(args.fresh, args.baseline, args.tolerance)
    if failures:
        print("\nperf-regression gate FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("perf-regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
