"""Static kernel cost table (``repro.quality.pallas_cost``) as a gated
bench: per-(kernel, shape) predicted FLOPs, HBM bytes, and arithmetic
intensity, recorded in the trajectory so a kernel edit that degrades
predicted intensity (or blows the VMEM budget, or breaks the cost-model
cross-check) fails CI the way a replay-throughput regression already does.

Fully deterministic — no timing, no TPU: the numbers are derived by
abstract interpretation, so any movement is a real change to a kernel's
blocking/indexing, never runner noise. This is the ground truth the
ROADMAP's kernel perf push (block-size autotuning DSE) searches over.
"""
from __future__ import annotations

from benchmarks.common import Row, emit
from repro.quality.pallas_cost import (analyze_shipped,
                                       crosscheck_cost_model)


def _short(kernel_path: str, shape: str) -> str:
    # "src/repro/kernels/flash_attention/kernel.py" -> "flash_attention"
    return f"{kernel_path.split('/')[-2]}[{shape}]"


def run(fast: bool = False) -> list[Row]:
    costs, findings = analyze_shipped()
    check = crosscheck_cost_model(costs)
    rows = [
        Row("kernel_cost", "n_rows", float(len(costs)),
            "(kernel, shape) static cost rows", "count", len(costs) > 0),
        Row("kernel_cost", "n_findings", float(len(findings)),
            "RPL2xx resource findings", "count", not findings),
        Row("kernel_cost", "cost_model_agreement",
            1.0 if check["ok"] else 0.0,
            "analytic intensity inside static kernel envelope", "bool",
            check["ok"]),
    ]
    if costs:
        # the gated headline: the envelope edges. min_intensity guards the
        # memory-bound floor (rmsnorm), worst_intensity the compute side —
        # a kernel edit that collapses either shifts the whole cost model.
        intensities = [c["arithmetic_intensity"] for c in costs]
        rows += [
            Row("kernel_cost", "min_intensity", min(intensities),
                "envelope floor (memory-bound kernels)", "flops/B"),
            Row("kernel_cost", "max_intensity", max(intensities),
                "envelope ceiling (matmul-heavy kernels)", "flops/B"),
        ]
        for c in costs:
            name = _short(c["kernel"], c["shape"])
            rows += [
                Row("kernel_cost", f"{name}_intensity",
                    c["arithmetic_intensity"], "", "flops/B"),
                Row("kernel_cost", f"{name}_roofline_frac",
                    c["roofline_frac"], "", ""),
                Row("kernel_cost", f"{name}_vmem_mib",
                    c["vmem_bytes"] / (1024 * 1024), "", "MiB"),
            ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "kernel_cost")


if __name__ == "__main__":
    main()
