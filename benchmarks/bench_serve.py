"""Serving-cluster replay at Seren scale (§6.2 inference-shaped work).

Replays the full 1M-request diurnal+bursty trace (fast mode: 20k)
through ``repro.cluster.serve_replay`` — continuous batching with
per-event admission, prefill/decode disaggregation, paged KV with
LIFO eviction + recompute — and reports:

  * throughput — the headline 1M-request replay runs alone against an
    advisory wall target, and a fixed 100k-request probe interleaved
    with CPU calibration yields the ``events_per_calib_serve`` row that
    ``benchmarks.check_regression`` gates CI on (``events_per_calib``
    carries the same value under the trajectory-standard name);
  * SLOs — p50/p99 TTFT and TPOT plus attainment against the config
    targets, priced through the committed prefill/decode cost cells
    (``CostModel.load``, analytic fallback) — the headline rows are
    therefore dryrun-fingerprint-stamped (``DRYRUN_STAMPED_BENCHES``),
    while the *gated* probe prices hermetically via
    ``CostModel.analytic`` so the gate stays armed across cell-set
    changes;
  * KV pressure — eviction/recompute volume and the conservation law
    (evicted tokens == recompute prefill tokens) as a pass/fail row,
    plus a deliberately KV-starved world exercising eviction churn;
  * fault tolerance — the headline trace replayed again with the §5
    taxonomy striking the fleet (diagnosis-driven recovery, bounded
    retries, graceful degradation): the injected wall must stay <=2x
    the failure-free wall, the extended conservation law
    (evicted + killed == recomputed) is a pass/fail row, and an
    injected calibrated probe yields the gated
    ``events_per_calib_serve_faults``.

The full scorecard is written to ``artifacts/bench/serve_summary.json``
next to the standard row artifact.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import (ARTIFACTS, Row, calibrated_probe, emit,
                               run_worlds)
from repro.cluster import (SERVING_TAXONOMY, DiagnosisLoop, FailureInjector,
                           ServeReplayConfig, generate_requests,
                           replay_requests)
from repro.launch.cost_model import CostModel

N_REQ_FULL = 1_000_000           # one day of Seren-scale serving traffic
N_REQ_FAST = 20_000
N_REQ_PROBE = 100_000            # fixed CI-gate throughput probe
ARCH = "internlm-7b"

# 1M-request full-fleet replay on CPU: ~15 s quiet on the dev machine
# (~4M events through the vtime batching engine). Advisory bound sized
# for a throttled shared runner; the gated number is the calibrated
# probe below.
FULL_WALL_TARGET_S = 90.0


def _probe_cfg() -> ServeReplayConfig:
    """Hermetic probe config: analytic rates, no artifacts read."""
    return ServeReplayConfig(cost_model=CostModel.analytic((ARCH,)))


def _reset(reqs) -> None:
    """Reset the engine-written per-request state between replays of the
    same trace (ttft/done/decoded/evictions plus the fault-path fields)."""
    for r in reqs:
        r.ttft_min = r.done_min = float("inf")
        r.decoded = r.evictions = r.retries = 0
        r._res += 1
        r._pfe = 0
        r._pfi = -1
        r._skips = 0
        r._fcls = None


# -- parallel worlds (module-level: must pickle) ----------------------------

def _world_probe() -> float:
    """Calibrated engine-throughput probe on a fixed 100k-request trace
    (30-minute horizon, so fleet load matches the 1M/day headline)."""
    reqs = generate_requests(N_REQ_PROBE, seed=0, horizon_min=43.2)
    cfg = _probe_cfg()

    def workload() -> float:
        _reset(reqs)
        return replay_requests(reqs, cfg).events_processed

    return calibrated_probe(workload)


def _world_faults() -> tuple:
    """Calibrated throughput probe with the §5 taxonomy striking the
    fleet: every round rebuilds the injector + diagnosis loop from fixed
    seeds, so each round injects the identical failure schedule and the
    measured work (teardown, diagnosis, retries, degraded admission) is
    round-invariant. Returns ``(calib, faults_summary)``."""
    reqs = generate_requests(N_REQ_PROBE, seed=0, horizon_min=43.2)
    last = {}

    def workload() -> float:
        nonlocal last
        _reset(reqs)
        cfg = ServeReplayConfig(
            cost_model=CostModel.analytic((ARCH,)),
            injector=FailureInjector(SERVING_TAXONOMY, seed=7,
                                     rate_scale=500.0),
            diagnosis=DiagnosisLoop(n_variants=4, flavor="serve"))
        res = replay_requests(reqs, cfg)
        last = res.summary()["faults"]
        return res.events_processed

    return calibrated_probe(workload), last


def _world_kv_tight() -> dict:
    """KV-starved fleet: quarter-size page pool forces eviction churn;
    returns the summary so eviction/recompute accounting lands in rows."""
    reqs = generate_requests(N_REQ_FAST, seed=2, horizon_min=30.0)
    cfg = ServeReplayConfig(cost_model=CostModel.analytic((ARCH,)),
                            kv_pages=1024, n_decode=8, n_prefill=2)
    return replay_requests(reqs, cfg).summary()


def run(fast: bool = False) -> list[Row]:
    n_req = N_REQ_FAST if fast else N_REQ_FULL
    horizon = 30.0 if fast else 1440.0
    reqs = generate_requests(n_req, seed=0, horizon_min=horizon)

    # 1) headline: full-fleet replay priced off the committed cells —
    #    runs alone so the wall number is uncontended
    cm = CostModel.load(archs=(ARCH,))
    t0 = time.perf_counter()
    res = replay_requests(reqs, ServeReplayConfig(cost_model=cm))
    wall = time.perf_counter() - t0
    s = res.summary()

    # 1b) same trace with the §5 taxonomy striking the fleet — also alone,
    #     so the injected-vs-failure-free wall ratio is apples-to-apples
    #     (the acceptance bound: fault machinery <= 2x the clean replay)
    _reset(reqs)
    dloop = DiagnosisLoop(n_variants=1, flavor="serve")
    for cls in SERVING_TAXONOMY:
        dloop.verdict(cls)  # prewarm the per-(class, variant) verdict cache
        # so the timed region measures the event-loop fault machinery, not
        # the diagnosis pipeline's one-time warm-up (production reality
        # too: continuous learning makes repeat incidents cheap rule hits)
    inj_cfg = ServeReplayConfig(
        cost_model=cm,
        injector=FailureInjector(SERVING_TAXONOMY, seed=7, rate_scale=500.0),
        diagnosis=dloop)
    t0 = time.perf_counter()
    res_inj = replay_requests(reqs, inj_cfg)
    wall_inj = time.perf_counter() - t0
    s_inj = res_inj.summary()
    inj_ratio = wall_inj / max(wall, 1e-9)
    inj_conserved = (res_inj.evicted_tokens + res_inj.killed_tokens
                     == res_inj.recompute_prefill_tokens)

    # 2) the calibrated CI-gate probes and the KV-pressure world overlap
    out = run_worlds({"probe": (_world_probe, ()),
                      "faults": (_world_faults, ()),
                      "kv_tight": (_world_kv_tight, ())})
    calib = out["probe"]
    calib_faults, probe_faults = out["faults"]
    tight = out["kv_tight"]

    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "serve_summary.json"), "w") as f:
        json.dump({"summary": s, "kv_tight": tight,
                   "faults": s_inj["faults"],
                   "probe_faults": probe_faults}, f, indent=1)

    slo = s["slo"]
    kv = s["kv"]
    wall_target = 30.0 if fast else FULL_WALL_TARGET_S
    conserved = (kv["evicted_tokens"] == kv["recompute_prefill_tokens"]
                 and tight["kv"]["evicted_tokens"]
                 == tight["kv"]["recompute_prefill_tokens"])
    rows = [
        Row("serve", "n_requests", float(n_req),
            ">=1M requests (full mode)", "", fast or n_req >= 1_000_000),
        Row("serve", "replay_wall_s", wall,
            f"<={wall_target:.0f} s on CPU", "s", wall <= wall_target),
        Row("serve", "events_per_sec",
            s["events_processed"] / max(wall, 1e-9), "", "ev/s"),
        # the gated rows: "events_per_calib" is the trajectory-standard
        # name, "events_per_calib_serve" the bench-specific alias — same
        # hermetic measurement (see module docstring)
        Row("serve", "events_per_calib", calib,
            "CI regression gate (calibrated)", ""),
        Row("serve", "events_per_calib_serve", calib,
            "CI regression gate (calibrated)", ""),
        Row("serve", "events_per_calib_serve_faults", calib_faults,
            "CI regression gate (calibrated, faults injected)", ""),
        Row("serve", "completed", float(s["completed"]),
            "all admitted requests finish", "",
            s["completed"] + s["rejected"] == n_req),
        Row("serve", "ttft_p50_s", s["ttft"]["p50_s"], "", "s"),
        Row("serve", "ttft_p99_s", s["ttft"]["p99_s"],
            "burst tail (diurnal+bursty trace)", "s"),
        Row("serve", "tpot_p50_ms", s["tpot"]["p50_ms"],
            "near full-batch step time", "ms"),
        Row("serve", "tpot_p99_ms", s["tpot"]["p99_ms"], "", "ms"),
        Row("serve", "slo_ttft_attainment", slo["ttft_attainment"],
            f"vs {slo['ttft_target_s']:.0f} s target", ""),
        Row("serve", "slo_joint_attainment", slo["joint_attainment"],
            "TTFT and TPOT jointly", "",
            0.0 < slo["joint_attainment"] <= 1.0),
        Row("serve", "batch_mean_occupancy", s["batch"]["mean_occupancy"],
            f"max {s['batch']['max_batch']}", ""),
        Row("serve", "kv_peak_pages_frac", kv["peak_pages_frac"],
            "<=1 (conservative page bound)", "",
            kv["peak_pages_frac"] <= 1.0 + 1e-9),
        Row("serve", "kv_evictions", float(kv["evictions"]), "", ""),
        Row("serve", "kv_conservation_ok", float(conserved),
            "evicted == recomputed, both worlds", "", conserved),
        Row("serve", "replay_wall_inject_ratio", inj_ratio,
            "<=2x failure-free wall", "x", inj_ratio <= 2.0),
        Row("serve", "faults_injected",
            float(s_inj["faults"]["injected"]),
            "taxonomy must strike the fleet", "",
            s_inj["faults"]["injected"] > 0),
        Row("serve", "fault_conservation_ok", float(inj_conserved),
            "evicted + killed == recomputed", "", inj_conserved),
        Row("serve", "fault_drop_frac",
            s_inj["faults"]["drops"] / max(n_req, 1),
            "bounded-retry losses stay rare", "",
            s_inj["faults"]["drops"] / max(n_req, 1) <= 0.02),
        Row("serve", "fault_degraded_min",
            s_inj["faults"]["degraded_min"], "", "min"),
        Row("serve", "kv_tight_evictions",
            float(tight["kv"]["evictions"]),
            "starved pool must evict", "",
            tight["kv"]["evictions"] > 0),
        Row("serve", "kv_tight_joint_attainment",
            tight["slo"]["joint_attainment"],
            "<= headline (recompute tax)", ""),
        Row("serve", "decoded_tok_per_s",
            s["throughput"]["decoded_tok_per_s"], "", "tok/s"),
        Row("serve", "rates_source_calibrated",
            float(res.rates_source == "calibrated/calibrated"), "",
            "", None),
    ]
    emit(rows, "serve")
    return rows


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
