"""Benchmark runner: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (bench_checkpoint, bench_detection, bench_diagnosis,
                        bench_evalsched, bench_moe_comm, bench_pool,
                        bench_recovery, bench_replay, bench_roofline,
                        bench_trace)
from benchmarks.common import emit

BENCHES = {
    "trace": bench_trace,              # §3, Fig. 2/3/4/6/17
    "replay": bench_replay,            # §3.2+§5 failure-aware replay
    "pool": bench_pool,                # §6.1x§6.2 elastic capacity pool
    "checkpoint": bench_checkpoint,    # §6.1 async ckpt 3.6~58.7x
    "diagnosis": bench_diagnosis,      # §6.1 Fig. 15, Table 3, ~90%
    "detection": bench_detection,      # §6.1 two-round sweep
    "evalsched": bench_evalsched,      # §6.2 Fig. 16, 1.3x/1.8x
    "recovery": bench_recovery,        # §5.3 / Fig. 14
    "moe_comm": bench_moe_comm,        # Appendix A.6
    "roofline": bench_roofline,        # §Roofline (dry-run artifacts)
}
# heavyweight (forces 512 XLA host devices; run explicitly):
#   python -m benchmarks.bench_parallelism   # Fig. 10/11 V1-vs-V2


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = []
    for name, mod in BENCHES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            emit(mod.run(args.fast), name)
            print(f"# {name} done in {time.time() - t0:.1f}s\n")
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
