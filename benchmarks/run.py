"""Benchmark runner: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME] [--profile]

After a run that produced all four gated throughput artifacts
(replay/pool/evalsched/serve), the runner consolidates their ``events_per_calib``
values into ``BENCH_replay.json`` — a per-commit *trajectory* of the
calibrated throughput history, including the replay bench's per-knob rows
(``replay_legacy`` / ``replay_placement`` / ``replay_best_effort`` /
``replay_full``) so each subsystem's cost is tracked per commit, not just
the aggregate. The fresh file extends the committed baseline's history
(``artifacts/bench/BENCH_replay.json``), so CI uploads carry the whole
perf history across PRs instead of one point per run.

``--profile`` additionally runs ``benchmarks.profile_replay`` (cProfile
over a full-feature replay, top-25 cumulative table to
``artifacts/bench/profile_replay.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import time
import traceback

from benchmarks import (bench_checkpoint, bench_detection, bench_diagnosis,
                        bench_evalsched, bench_kernel_cost, bench_moe_comm,
                        bench_pool, bench_recovery, bench_replay,
                        bench_roofline, bench_serve, bench_trace)
from benchmarks.common import (ARTIFACTS, emit, set_dryrun_stamp,
                               set_pallas_cost_stamp, set_replint_stamp)

# benches whose calibrated throughput forms the consolidated trajectory
TRAJECTORY_BENCHES = ("replay", "pool", "evalsched", "serve")
# per-knob replay rows recorded alongside (trajectory key -> source metric);
# optional: absent from an artifact (e.g. a pre-PR-5 baseline) -> skipped.
# The roofline/moe_comm keys track the calibrated cost-model rows in the
# same per-commit history once the dryrun artifacts exist in CI.
TRAJECTORY_EXTRAS = {
    "replay_legacy": ("replay", "events_per_calib_legacy"),
    "replay_placement": ("replay", "events_per_calib_placement"),
    "replay_best_effort": ("replay", "events_per_calib_best_effort"),
    "replay_full": ("replay", "events_per_calib_full"),
    "roofline_n_cells": ("roofline", "n_cells"),
    "roofline_worst_frac": ("roofline", "worst_roofline_frac"),
    "moe_deepseek_over_dense": ("moe_comm", "deepseek_over_dense"),
    "moe_mixtral_over_dense": ("moe_comm", "mixtral_over_dense"),
    "serve_joint_attainment": ("serve", "slo_joint_attainment"),
    "serve_decoded_tok_per_s": ("serve", "decoded_tok_per_s"),
    "serve_faults": ("serve", "events_per_calib_serve_faults"),
    "serve_inject_ratio": ("serve", "replay_wall_inject_ratio"),
    # static kernel cost envelope: deterministic, so any movement in the
    # history is a real kernel blocking/indexing change
    "kernel_min_intensity": ("kernel_cost", "min_intensity"),
    "kernel_max_intensity": ("kernel_cost", "max_intensity"),
}
TRAJECTORY_BASELINE = os.path.join("artifacts", "bench", "BENCH_replay.json")

# replint verdict for this run's tree; filled by main() before any bench
# runs, stamped into every artifact row set (benchmarks.common.emit) and
# the trajectory entry, and *gated* by check_regression — bench numbers
# recorded from a lint-dirty tree must never become baselines
_replint_verdict: dict | None = None


def _stamp_replint() -> dict:
    global _replint_verdict
    try:
        from repro.quality.lint import verdict
        # anchored at the repo root so bench runs from any cwd lint the
        # same tree (rule scoping matches on repro/-relative suffixes)
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        _replint_verdict = verdict((os.path.join(root, "src", "repro"),))
    except Exception as exc:  # noqa: BLE001 - a broken linter must not
        #                       kill the bench run; the stamp records it
        _replint_verdict = {"clean": False, "findings": -1,
                            "error": str(exc)}
    set_replint_stamp(_replint_verdict)
    state = "clean" if _replint_verdict.get("clean") else "DIRTY"
    print(f"# replint: tree is {state} "
          f"({_replint_verdict.get('findings', '?')} findings)")
    return _replint_verdict


def _stamp_pallas_cost() -> dict:
    """Static kernel resource verdict (RPL2xx + cost-model cross-check)
    for this run's tree, stamped into every artifact row set;
    ``check_regression`` refuses artifacts stamped pallas_cost-dirty the
    same way it refuses replint-dirty ones."""
    try:
        from repro.quality.pallas_cost import verdict
        v = verdict()
    except Exception as exc:  # noqa: BLE001 - a broken analyzer must not
        #                       kill the bench run; the stamp records it
        v = {"clean": False, "n_findings": -1, "cost_model_ok": False,
             "error": str(exc)}
    set_pallas_cost_stamp(v)
    state = "clean" if v.get("clean") else "DIRTY"
    print(f"# pallas_cost: kernels are {state} "
          f"({v.get('n_findings', '?')} findings, cost-model check "
          f"{'ok' if v.get('cost_model_ok') else 'FAILED'})")
    return v


def _stamp_dryrun() -> dict:
    """Record which dryrun artifact cells this run's cost-model benches
    consumed (arch list + calibration state, hashed to a fingerprint);
    ``check_regression`` refuses to compare roofline/moe_comm rows across
    differing fingerprints."""
    try:
        from repro.launch.cost_model import dryrun_provenance
        prov = dryrun_provenance()
    except Exception as exc:  # noqa: BLE001 - a broken loader must not
        #                       kill the bench run; the stamp records it
        prov = {"archs": [], "n_cells": 0, "n_calibrated": 0,
                "fingerprint": "00000000", "error": str(exc)}
    set_dryrun_stamp(prov)
    print(f"# dryrun artifacts: {prov['n_cells']} cells "
          f"({prov['n_calibrated']} calibrated, archs={prov['archs']}, "
          f"fingerprint {prov['fingerprint']})")
    return prov


def _run_label() -> str:
    """Commit-ish label for a trajectory entry: CI sha, else git, else
    'local'."""
    sha = os.environ.get("GITHUB_SHA", "")
    if not sha:
        try:
            sha = subprocess.run(["git", "rev-parse", "HEAD"],
                                 capture_output=True, text=True,
                                 timeout=10).stdout.strip()
        except (OSError, subprocess.SubprocessError):
            sha = ""
    return sha[:12] or "local"


def write_trajectory(artifacts_dir: str = ARTIFACTS,
                     baseline_path: str = TRAJECTORY_BASELINE,
                     label: str | None = None,
                     extra_ok: "set[str] | None" = None) -> dict | None:
    """Consolidate this run's gated ``events_per_calib`` values into
    ``<artifacts_dir>/BENCH_replay.json``, extending the committed
    baseline's history (same-label entries are replaced, so re-runs do not
    duplicate). Returns the written document, or ``None`` when any of the
    three gated artifacts is missing. The caller must ensure the artifacts
    were produced by *this* invocation — ``main`` only consolidates when
    every trajectory bench actually ran and succeeded, so a ``--only`` or
    partially-failed run can never relabel stale numbers as fresh."""
    entry: dict = {"label": label or _run_label(),
                   "date": time.strftime("%Y-%m-%d")}
    if _replint_verdict is not None:
        entry["replint_clean"] = bool(_replint_verdict.get("clean"))
    rows_by_bench: dict = {}
    for bench in TRAJECTORY_BENCHES:
        path = os.path.join(artifacts_dir, f"{bench}.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            rows = json.load(f)
        rows_by_bench[bench] = rows
        value = next((r["value"] for r in rows
                      if r["metric"] == "events_per_calib"), None)
        if value is None:
            return None
        entry[bench] = float(value)
    for key, (bench, metric) in TRAJECTORY_EXTRAS.items():
        rows = rows_by_bench.get(bench)
        if rows is None:
            # extras may live outside the gated trajectory benches (the
            # cost-model rows); read their artifacts on demand, but only
            # when the caller vouches the bench ran in this invocation
            # (``extra_ok``) — a stale on-disk file must not enter the
            # history. None (direct calls) keeps the permissive behavior.
            if extra_ok is not None and bench not in extra_ok:
                continue
            path = os.path.join(artifacts_dir, f"{bench}.json")
            if not os.path.exists(path):
                continue
            with open(path) as f:
                rows = json.load(f)
            rows_by_bench[bench] = rows
        value = next((r["value"] for r in rows if r["metric"] == metric),
                     None)
        if value is not None:
            entry[key] = float(value)
    history: list = []
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            history = json.load(f).get("history", [])
    history = [e for e in history if e.get("label") != entry["label"]]
    history.append(entry)
    doc = {"metric": "events_per_calib", "benches": list(TRAJECTORY_BENCHES),
           "history": history}
    out = os.path.join(artifacts_dir, "BENCH_replay.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# trajectory: {out} ({len(history)} entries)")
    return doc

BENCHES = {
    "trace": bench_trace,              # §3, Fig. 2/3/4/6/17
    "replay": bench_replay,            # §3.2+§5 failure-aware replay
    "pool": bench_pool,                # §6.1x§6.2 elastic capacity pool
    "checkpoint": bench_checkpoint,    # §6.1 async ckpt 3.6~58.7x
    "diagnosis": bench_diagnosis,      # §6.1 Fig. 15, Table 3, ~90%
    "detection": bench_detection,      # §6.1 two-round sweep
    "evalsched": bench_evalsched,      # §6.2 Fig. 16, 1.3x/1.8x
    "recovery": bench_recovery,        # §5.3 / Fig. 14
    "moe_comm": bench_moe_comm,        # Appendix A.6
    "roofline": bench_roofline,        # §Roofline (dry-run artifacts)
    "serve": bench_serve,              # §6.2 serving-cluster replay
    "kernel_cost": bench_kernel_cost,  # static RPL2xx kernel cost table
}
# heavyweight (forces 512 XLA host devices; run explicitly):
#   python -m benchmarks.bench_parallelism   # Fig. 10/11 V1-vs-V2


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--profile", action="store_true",
                    help="also run benchmarks.profile_replay (cProfile "
                         "hot-path table -> profile_replay.json)")
    args = ap.parse_args()
    _stamp_replint()
    _stamp_pallas_cost()
    _stamp_dryrun()
    failures = []
    succeeded = []
    for name, mod in BENCHES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            emit(mod.run(args.fast), name)
            succeeded.append(name)
            print(f"# {name} done in {time.time() - t0:.1f}s\n")
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}")
    if args.profile:
        from benchmarks import profile_replay
        try:
            profile_replay.main(["--fast"] if args.fast else [])
        except Exception:  # noqa: BLE001
            failures.append("profile_replay")
            print(f"# profile_replay FAILED:\n{traceback.format_exc()}")
    if all(b in succeeded for b in TRAJECTORY_BENCHES):
        # only artifacts produced by THIS invocation may enter the
        # trajectory — a --only or partially-failed run must not relabel
        # stale on-disk numbers as a fresh history point
        write_trajectory(extra_ok=set(succeeded))
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
