"""Render §Dry-run and §Roofline markdown tables from the artifacts.

  PYTHONPATH=src python -m benchmarks.render_experiments > artifacts/tables.md
"""
from __future__ import annotations

import json
import os

from repro.launch.roofline import cell_roofline, load_cells


def dryrun_table(mesh: str) -> str:
    cells = load_cells(f"artifacts/dryrun/{mesh}")
    out = [f"### {mesh} mesh ({'2x16x16' if mesh == 'multi' else '16x16'})",
           "",
           "| arch | shape | status | lower s | compile s | args/dev | "
           "temp/dev | HLO flops/dev (scan-once) | coll bytes/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in cells:
        if r.get("status") != "ok":
            continue
        mem = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r.get('lower_s', 0):.1f} "
            f"| {r.get('compile_s', 0):.1f} "
            f"| {mem.get('argument_size_in_bytes', 0) / 2**30:.2f} GiB "
            f"| {mem.get('temp_size_in_bytes', 0) / 2**30:.2f} GiB "
            f"| {r.get('cost', {}).get('flops', 0):.3e} "
            f"| {r['collectives']['total_bytes_per_device'] / 2**30:.2f} GiB |")
    return "\n".join(out)


def roofline_table() -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells("artifacts/dryrun/single"):
        r = cell_roofline(rec)
        if r is None:
            continue
        out.append(f"| {r.arch} | {r.shape} | {r.compute_s:.3e} "
                   f"| {r.memory_s:.3e} | {r.collective_s:.3e} "
                   f"| **{r.dominant}** | {r.useful_ratio:.2f} "
                   f"| {r.roofline_frac:.3f} |")
    return "\n".join(out)


def skip_table() -> str:
    return "\n".join([
        "| arch | shape | reason |", "|---|---|---|",
        *(f"| {a} | long_500k | pure full-attention decode state at 500k "
          "is unbounded |"
          for a in ("smollm-360m", "nemotron-4-15b", "internvl2-2b",
                    "whisper-large-v3", "deepseek-v2-lite-16b"))])


def main() -> None:
    print("## Dry-run matrix\n")
    print(dryrun_table("single"))
    print()
    print(dryrun_table("multi"))
    print("\n### Skipped cells (5 per mesh)\n")
    print(skip_table())
    print("\n## Roofline (single pod, calibrated)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
