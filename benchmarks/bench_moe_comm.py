"""Appendix A.6 analog: MoE pretraining is communication-bound relative to
dense — the paper saw much lower GPU utilization for Mixtral-style MoE
because "the MoE model requires frequent all-to-all communication".

Metric: collective bytes moved per *useful* (active-param) FLOP, from the
calibrated dry-run artifacts. The MoE archs (gshard expert dispatch + its
all-to-alls, plus the fatter ZeRO gathers over mostly-inactive expert
weights) must move several times more bytes per useful FLOP than a dense
model of similar scale.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import Row, emit
from repro.config import get_arch
from repro.launch.roofline import model_flops_per_device

ART = "artifacts/dryrun/single"


def _comm_per_flop(arch: str) -> tuple[float, float]:
    with open(os.path.join(ART, arch, "train_4k.json")) as f:
        rec = json.load(f)
    cal = rec.get("calibrated", {})
    coll = cal.get("coll_total",
                   rec["collectives"]["total_bytes_per_device"])
    a2a = cal.get("coll_all-to-all", 0.0)
    mf = model_flops_per_device(get_arch(arch), "train", rec["seq_len"],
                                rec["global_batch"], rec["n_devices"])
    return coll / mf, a2a


def run(fast: bool = False) -> list[Row]:
    try:
        moe_ratio, moe_a2a = _comm_per_flop("deepseek-v2-lite-16b")
        mix_ratio, mix_a2a = _comm_per_flop("mixtral-8x22b")
        dense_ratio, _ = _comm_per_flop("nemotron-4-15b")
    except FileNotFoundError:
        return [Row("moe_comm", "skipped_no_dryrun_artifacts", 0.0,
                    "run repro.launch.dryrun --calibrate first", "", None)]
    rows = [
        Row("moe_comm", "deepseek_coll_bytes_per_useful_flop", moe_ratio,
            "", "B/flop"),
        Row("moe_comm", "mixtral_coll_bytes_per_useful_flop", mix_ratio,
            "", "B/flop"),
        Row("moe_comm", "dense_coll_bytes_per_useful_flop", dense_ratio,
            "", "B/flop"),
        Row("moe_comm", "deepseek_over_dense", moe_ratio / dense_ratio,
            "MoE comm-heavier per useful FLOP (A.6)", "x",
            moe_ratio / dense_ratio > 1.5),
        Row("moe_comm", "mixtral_over_dense", mix_ratio / dense_ratio,
            "MoE comm-heavier per useful FLOP (A.6)", "x",
            mix_ratio / dense_ratio > 1.5),
        Row("moe_comm", "deepseek_a2a_gib_per_step", moe_a2a / 2 ** 30,
            "expert-dispatch all-to-all present", "GiB", moe_a2a > 0),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "moe_comm")


if __name__ == "__main__":
    main()
