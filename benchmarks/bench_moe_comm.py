"""Appendix A.6 analog: MoE pretraining is communication-bound relative to
dense — the paper saw much lower GPU utilization for Mixtral-style MoE
because "the MoE model requires frequent all-to-all communication".

Metric: collective bytes moved per *useful* (active-param) FLOP, from the
cost model's train cells. Cells come from the calibrated dry-run artifacts
when present (CI's ``dryrun-smoke`` job produces them); archs without
artifacts fall back to the model's deterministic analytic cells, so the
bench always emits its gated rows — ``n_calibrated_cells`` /
``n_analytic_cells`` report which kind backed this run, and the dryrun
provenance stamp keeps ``check_regression`` from comparing rows built
from different cell sets. The MoE archs (gshard expert dispatch + its
all-to-alls, plus the fatter ZeRO gathers over mostly-inactive expert
weights) must move several times more bytes per useful FLOP than a dense
model of similar scale.
"""
from __future__ import annotations

from benchmarks.common import Row, emit
from repro.launch.cost_model import CostModel

MOE_ARCHS = ("deepseek-v2-lite-16b", "mixtral-8x22b")
DENSE_ARCH = "nemotron-4-15b"


def _comm_per_flop(model: CostModel, arch: str) -> tuple[float, float]:
    cell = model.cell(arch)
    if cell is None:
        raise KeyError(f"no train cell for {arch!r}")
    return cell.collective_bytes / cell.model_flops, cell.a2a_bytes


def run(fast: bool = False) -> list[Row]:
    model = CostModel.load(archs=MOE_ARCHS + (DENSE_ARCH,))
    moe_ratio, moe_a2a = _comm_per_flop(model, "deepseek-v2-lite-16b")
    mix_ratio, mix_a2a = _comm_per_flop(model, "mixtral-8x22b")
    dense_ratio, _ = _comm_per_flop(model, DENSE_ARCH)
    sources = [model.cell(a).source for a in MOE_ARCHS + (DENSE_ARCH,)]
    n_analytic = sum(1 for s in sources if s == "analytic")
    rows = [
        Row("moe_comm", "deepseek_coll_bytes_per_useful_flop", moe_ratio,
            "", "B/flop"),
        Row("moe_comm", "mixtral_coll_bytes_per_useful_flop", mix_ratio,
            "", "B/flop"),
        Row("moe_comm", "dense_coll_bytes_per_useful_flop", dense_ratio,
            "", "B/flop"),
        Row("moe_comm", "deepseek_over_dense", moe_ratio / dense_ratio,
            "MoE comm-heavier per useful FLOP (A.6)", "x",
            moe_ratio / dense_ratio > 1.5),
        Row("moe_comm", "mixtral_over_dense", mix_ratio / dense_ratio,
            "MoE comm-heavier per useful FLOP (A.6)", "x",
            mix_ratio / dense_ratio > 1.5),
        Row("moe_comm", "deepseek_a2a_gib_per_step", moe_a2a / 2 ** 30,
            "expert-dispatch all-to-all present", "GiB", moe_a2a > 0),
        Row("moe_comm", "mixtral_a2a_gib_per_step", mix_a2a / 2 ** 30,
            "", "GiB"),
        Row("moe_comm", "n_calibrated_cells",
            float(len(sources) - n_analytic),
            "cells backed by dryrun artifacts", "count"),
        Row("moe_comm", "n_analytic_cells", float(n_analytic),
            "cells from the analytic fallback", "count"),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "moe_comm")


if __name__ == "__main__":
    main()
