"""Failure-aware trace replay at scale (§3.2 + §5, Figs. 13-14 analogues).

Replays a large synthetic Kalos trace through the unified scheduler/failure
engine and reports:

  * throughput — a >=100k-job trace with failure injection must replay in
    well under 60 s on CPU (the engine's indexed dispatch target);
  * parity — with injection disabled the engine must reproduce
    ``simulate_queue``'s queue delays bit-exactly on the same trace;
  * the paper's failure characterization — per-jtype queue-delay quantiles,
    restart counts, lost GPU hours by failure class, cordon/detection
    activity.

The full per-jtype summary is written to
``artifacts/bench/replay_summary.json`` next to the standard row artifact.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import ARTIFACTS, Row, emit
from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           generate_jobs, replay_trace, simulate_queue)

N_JOBS_FULL = 200_000
N_JOBS_FAST = 20_000


def run(fast: bool = False) -> list[Row]:
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    jobs = generate_jobs(KALOS, seed=0, n_jobs=n_jobs)

    # 1) baseline queue replay (the old simulate_queue semantics)
    t0 = time.perf_counter()
    simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.97)
    t_base = time.perf_counter() - t0
    base_delays = [j.queue_min for j in jobs]

    # 2) failure-injected replay
    inj = FailureInjector(seed=1, rate_scale=2.0)
    t0 = time.perf_counter()
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(injector=inj))
    t_inj = time.perf_counter() - t0
    s = res.summary()

    # 3) parity: injection off must reproduce simulate_queue exactly
    replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                 config=ReplayConfig(injector=None))
    max_dq = max(abs(a - j.queue_min)
                 for a, j in zip(base_delays, jobs))

    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "replay_summary.json"), "w") as f:
        json.dump(s, f, indent=1)

    q = s["queue_delay_quantiles"]
    cls = s["lost_gpu_hours_by_class"]
    rows = [
        Row("replay", "n_jobs", float(n_jobs), ">=100k (full mode)", "",
            fast or n_jobs >= 100_000),
        Row("replay", "inject_replay_wall_s", t_inj, "<60 s on CPU", "s",
            t_inj < 60.0),
        Row("replay", "events_per_sec",
            s["events_processed"] / max(t_inj, 1e-9), "", "ev/s"),
        Row("replay", "noinject_parity_max_dq_min", max_dq,
            "0 (bit-exact vs simulate_queue)", "min", max_dq == 0.0),
        Row("replay", "baseline_queue_wall_s", t_base, "", "s"),
        Row("replay", "eval_queue_p50_min", q["evaluation"]["p50_min"],
            "longest class (Fig. 6d inversion)", "min",
            all(q["evaluation"]["p50_min"] >= v["p50_min"]
                for v in q.values())),
        Row("replay", "pretrain_queue_p99_min", q["pretrain"]["p99_min"],
            "~0 (reservation)", "min"),
        Row("replay", "total_restarts", float(s["total_restarts"]),
            ">0 with injection", "", s["total_restarts"] > 0),
        Row("replay", "total_lost_gpu_hours", s["total_lost_gpu_hours"],
            "dominated by pretrain (§5.1)", "GPUh",
            s["lost_gpu_hours_by_jtype"]["pretrain"]["gpu_hours"]
            >= 0.5 * max(s["total_lost_gpu_hours"], 1e-9)),
        Row("replay", "hardware_failures",
            float(cls.get("hardware", {}).get("failures", 0)), "", ""),
        Row("replay", "infra_failures",
            float(cls.get("infra", {}).get("failures", 0)), "", ""),
        Row("replay", "cordon_events", float(s["cordon_events"]),
            "two-round sweep fired", "", s["cordon_events"] > 0),
        Row("replay", "detection_probes", float(s["detection_probes"]),
            "", ""),
        Row("replay", "killed_jobs", float(s["killed_jobs"]), "", ""),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "replay")


if __name__ == "__main__":
    main()
