"""Failure-aware trace replay at scale (§3.2 + §5 + §6, Figs. 13-14).

Replays the full 1M-job Seren trace (fast mode: 20k-job Kalos) through the
unified scheduler/failure engine with §6.1 diagnosis-in-the-loop recovery
(elastic shrink / in-place restart / cordon+requeue) and reports:

  * throughput — the 1M-job injected+diagnosed replay with the full
    elastic capacity pool attached (opportunistic free-pool regrowth +
    node-local placement, best-effort revocable leases, evalsched trial
    borrowing + head-delay tracking) must finish within
    ``FULL_WALL_TARGET_S`` on CPU, and fixed probes run in *both* modes
    yield the CPU-calibrated ``events_per_calib`` rows that
    ``benchmarks.check_regression`` gates CI on — one row per feature
    knob (``legacy`` / ``placement`` / ``best_effort`` / ``full``), so a
    regression in one subsystem's cost is visible per knob instead of
    hiding in the aggregate;
  * parity — with injection disabled the engine must reproduce
    ``simulate_queue``'s queue delays bit-exactly on the same trace;
  * the paper's failure characterization — per-jtype queue-delay quantiles,
    restart counts, lost GPU hours by failure class, cordon/detection
    activity, plus the recovery side: per-class diagnosis verdicts (>=95%
    of synthesized hardware logs must come back ``hardware``) and the
    policy mix the verdicts picked.

The headline injected replay runs alone (clean wall measurement); the
baseline-queue, parity and probe worlds then run in parallel via
``benchmarks.common.run_worlds`` — they are independent replays of
deterministically regenerated traces, and running them sequentially used
to dominate the suite's wall time. Each probe interleaves its own CPU
calibration, which is what keeps the gated ratios robust to the mutual
contention (see ``calibrated_probe``).

The full per-jtype summary is written to
``artifacts/bench/replay_summary.json`` next to the standard row artifact.
"""
from __future__ import annotations

import array
import json
import os
import time

from benchmarks.common import (ARTIFACTS, Row, calibrated_probe, emit,
                               run_worlds)
from repro.cluster import (KALOS, SEREN, DiagnosisLoop, FailureInjector,
                           ReplayConfig, generate_jobs, recovery_stats,
                           replay_trace, simulate_queue)
from repro.core.evalsched import STORAGE_SPEC, TrialBorrower

N_JOBS_FULL = 1_000_000          # the full Seren trace (paper §3, Fig. 4)
N_JOBS_FAST = 20_000
N_JOBS_PROBE = 100_000           # fixed CI-gate throughput probe

# 1M injected+diagnosed+pool replay on CPU. The PR 5 hot-path rewrite
# (incremental NodeLedger indices, dirty-flag reconcile, inlined dispatch
# fast paths, GC paused across the loop) brought the full-feature wall
# back to ~PR 2 levels: ~16 s quiet (back-to-back vs ~31 s for the PR 4
# engine on the same machine). Shared-runner CPU throttling swings even
# CPU time up to ~2x run-to-run, so the *gated* numbers are the
# calibrated events_per_calib probes and this wall target is an advisory
# sanity bound sized for a throttled runner.
FULL_WALL_TARGET_S = 40.0

BEST_EFFORT_FRAC = 0.3           # share of eligible jobs on revocable leases

# throughput-probe feature matrix: metric suffix -> (best_effort jobs,
# placement, borrower). "legacy" is the PR-3-era configuration (diagnosis +
# elastic + opportunistic regrowth, node-less); each later knob adds one
# subsystem so the per-knob rows isolate its cost.
PROBE_CONFIGS = {
    "legacy": (False, False, False),
    "placement": (False, True, False),
    "best_effort": (True, False, False),
    "full": (True, True, True),
}


def _injected_config(diagnosis=None) -> ReplayConfig:
    # the full elastic capacity pool: diagnosis-driven elastic shrink,
    # opportunistic regrowth (on by default), node-local placement with
    # best-effort revocable leases, and eval trials borrowing free-pool
    # GPUs — the "full" probe therefore gates the whole ledger overhead too
    borrower = TrialBorrower.from_suite(63, repeat=200, spec=STORAGE_SPEC)
    return ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                        diagnose=diagnosis is None, diagnosis=diagnosis,
                        elastic=True, placement=True,
                        reshard_cost_min=1.0, borrower=borrower)


# -- parallel worlds (module-level: must pickle) ----------------------------

def _world_queue(fast: bool) -> tuple[float, array.array]:
    """Baseline queue replay (the old simulate_queue semantics)."""
    spec = KALOS if fast else SEREN
    jobs = generate_jobs(spec, seed=0,
                         n_jobs=N_JOBS_FAST if fast else N_JOBS_FULL,
                         best_effort_frac=BEST_EFFORT_FRAC)
    t0 = time.perf_counter()
    simulate_queue(jobs, spec.n_gpus, reserved_frac=0.97 if fast else 0.95)
    wall = time.perf_counter() - t0
    return wall, array.array("d", (j.queue_min for j in jobs))


def _world_parity(fast: bool) -> array.array:
    """No-injection replay of the same trace: must equal _world_queue."""
    spec = KALOS if fast else SEREN
    jobs = generate_jobs(spec, seed=0,
                         n_jobs=N_JOBS_FAST if fast else N_JOBS_FULL,
                         best_effort_frac=BEST_EFFORT_FRAC)
    replay_trace(jobs, spec.n_gpus, reserved_frac=0.97 if fast else 0.95,
                 config=ReplayConfig(injector=None))
    return array.array("d", (j.queue_min for j in jobs))


def _world_probe(key: str) -> float:
    """One calibrated throughput probe (fixed 100k-job Kalos shape).

    The probe process keeps one warm ``DiagnosisLoop`` across its rounds —
    mirroring production, where repeat incidents are cheap rule hits — so
    the gate measures the replay engine, not pipeline warmup."""
    best_effort, placement, borrow = PROBE_CONFIGS[key]
    probe_jobs = generate_jobs(
        KALOS, seed=0, n_jobs=N_JOBS_PROBE,
        best_effort_frac=BEST_EFFORT_FRAC if best_effort else 0.0)
    loop = DiagnosisLoop()

    def workload() -> float:
        cfg = ReplayConfig(
            injector=FailureInjector(seed=1, rate_scale=2.0),
            diagnosis=loop, elastic=True, placement=placement,
            reshard_cost_min=1.0 if placement else 0.0,
            borrower=TrialBorrower.from_suite(63, repeat=200,
                                              spec=STORAGE_SPEC)
            if borrow else None)
        return replay_trace(probe_jobs, KALOS.n_gpus, reserved_frac=0.97,
                            config=cfg).events_processed

    return calibrated_probe(workload)


def run(fast: bool = False) -> list[Row]:
    spec = KALOS if fast else SEREN
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    # spare-pool contention calibrated per trace: at 1M jobs the Seren
    # spare pool saturates above ~0.95 (every best-effort class then waits
    # forever) while Kalos at 20k needs 0.97 to show the eval inversion
    frac = 0.97 if fast else 0.95
    jobs = generate_jobs(spec, seed=0, n_jobs=n_jobs,
                         best_effort_frac=BEST_EFFORT_FRAC)

    # 1) headline: failure-injected replay with diagnosis-driven elastic
    #    recovery — runs alone so the wall number is uncontended
    t0 = time.perf_counter()
    res = replay_trace(jobs, spec.n_gpus, reserved_frac=frac,
                       config=_injected_config())
    t_inj = time.perf_counter() - t0
    s = res.summary()
    rec = recovery_stats(res)

    # 2) everything else overlaps: baseline queue replay, the no-inject
    #    parity world, and the four per-knob calibrated probes
    worlds = {"queue": (_world_queue, (fast,)),
              "parity": (_world_parity, (fast,))}
    worlds.update({f"probe_{k}": (_world_probe, (k,))
                   for k in PROBE_CONFIGS})
    out = run_worlds(worlds)
    t_base, base_delays = out["queue"]
    parity_delays = out["parity"]
    max_dq = max((abs(a - b) for a, b in zip(base_delays, parity_delays)),
                 default=0.0)
    calib = {k: out[f"probe_{k}"] for k in PROBE_CONFIGS}

    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "replay_summary.json"), "w") as f:
        json.dump({"summary": s, "recovery": rec}, f, indent=1)

    q = s["queue_delay_quantiles"]
    cls = s["lost_gpu_hours_by_class"]
    pol = rec["policies"]
    hw_recall = rec["hardware_verdict_recall"] or 0.0
    wall_target = 60.0 if fast else FULL_WALL_TARGET_S
    rows = [
        Row("replay", "n_jobs", float(n_jobs),
            ">=1M Seren (full mode)", "", fast or n_jobs >= 1_000_000),
        Row("replay", "inject_replay_wall_s", t_inj,
            f"<={wall_target:.0f} s on CPU", "s", t_inj <= wall_target),
        Row("replay", "events_per_sec",
            s["events_processed"] / max(t_inj, 1e-9), "", "ev/s"),
        # the gated rows: "events_per_calib" keeps its historical meaning
        # (the heaviest configuration) and "events_per_calib_full" is the
        # same measurement under its per-knob name; the per-knob deltas
        # price each subsystem separately
        Row("replay", "events_per_calib", calib["full"],
            "CI regression gate (calibrated)", ""),
        Row("replay", "events_per_calib_full", calib["full"],
            "CI regression gate (calibrated)", ""),
        Row("replay", "events_per_calib_legacy", calib["legacy"],
            "diag+elastic+regrow, node-less", ""),
        Row("replay", "events_per_calib_placement", calib["placement"],
            "legacy + NodeLedger placement", ""),
        Row("replay", "events_per_calib_best_effort", calib["best_effort"],
            "legacy + revocable-lease tier", ""),
        Row("replay", "noinject_parity_max_dq_min", max_dq,
            "0 (bit-exact vs simulate_queue)", "min", max_dq == 0.0),
        Row("replay", "baseline_queue_wall_s", t_base, "", "s"),
        Row("replay", "eval_queue_p50_min", q["evaluation"]["p50_min"],
            "longest class (Fig. 6d inversion)", "min",
            all(q["evaluation"]["p50_min"] >= v["p50_min"]
                for v in q.values())),
        Row("replay", "pretrain_queue_p99_min", q["pretrain"]["p99_min"],
            "~0 (reservation)", "min"),
        Row("replay", "total_restarts", float(s["total_restarts"]),
            ">0 with injection", "", s["total_restarts"] > 0),
        Row("replay", "total_lost_gpu_hours", s["total_lost_gpu_hours"],
            "dominated by pretrain (§5.1)", "GPUh",
            # a 20k fast trace is sampling-noise territory (one long
            # un-checkpointed debug job can dominate); assert at full scale
            None if fast else
            s["lost_gpu_hours_by_jtype"]["pretrain"]["gpu_hours"]
            >= 0.5 * max(s["total_lost_gpu_hours"], 1e-9)),
        Row("replay", "hardware_failures",
            float(cls.get("hardware", {}).get("failures", 0)), "", ""),
        Row("replay", "infra_failures",
            float(cls.get("infra", {}).get("failures", 0)), "", ""),
        Row("replay", "cordon_events", float(s["cordon_events"]),
            "two-round sweep fired", "", s["cordon_events"] > 0),
        Row("replay", "detection_probes", float(s["detection_probes"]),
            "", ""),
        Row("replay", "killed_jobs", float(s["killed_jobs"]), "", ""),
        # -- §6.1 diagnosis-in-the-loop recovery ----------------------------
        Row("replay", "hardware_verdict_recall", hw_recall,
            ">=0.95 classified hardware", "", hw_recall >= 0.95),
        Row("replay", "diagnosis_pipeline_runs",
            float(res.diagnosis_pipeline_runs),
            "bounded by variant cache", "",
            0 < res.diagnosis_pipeline_runs <= 3 * 32),
        Row("replay", "elastic_shrinks", float(res.elastic_shrinks),
            "wide hardware-verdict jobs shrink", "",
            res.elastic_shrinks > 0),
        Row("replay", "elastic_regrows", float(res.elastic_regrows), "", ""),
        Row("replay", "inplace_restarts",
            float(pol.get("inplace", {}).get("count", 0)),
            "transient verdicts restart in place", "",
            pol.get("inplace", {}).get("count", 0) > 0),
    ]
    # -- elastic capacity pool (free-pool regrowth + trial borrowing) -------
    pool = s["pool"]
    hd = s["head_delay"]
    rows += [
        Row("replay", "pool_regrows", float(pool["regrowth"]["pool_regrows"]),
            "shrunken jobs reclaim width from the free pool", "",
            pool["regrowth"]["pool_regrows"] > 0),
        Row("replay", "pool_regrown_gpus",
            float(pool["regrowth"]["pool_regrown_gpus"]), "", ""),
        Row("replay", "borrowed_gpu_hours",
            pool["borrow"].get("borrowed_gpu_hours", 0.0),
            "eval trials ran on leased free-pool GPUs", "GPUh",
            pool["borrow"].get("borrowed_gpu_hours", 0.0) > 0),
        Row("replay", "borrow_preemptions",
            float(pool["borrow"].get("preemptions", 0)),
            "leases revoked by dispatch/regrowth", ""),
        Row("replay", "head_delay_p50_min", hd["p50_min"],
            "blocked-head wait tail", "min", hd["n"] > 0),
        Row("replay", "head_delay_p95_min", hd["p95_min"], "", "min"),
        Row("replay", "head_delay_p99_min", hd["p99_min"], "", "min"),
    ]
    # -- node-local leases: placement + best-effort tier --------------------
    be = pool["best_effort"]
    placement = s["placement"]
    rows += [
        Row("replay", "best_effort_lease_starts", float(be["lease_starts"]),
            "checkpointed jobs on revocable leases", "",
            be["lease_starts"] > 0),
        Row("replay", "best_effort_revocations", float(be["revocations"]),
            "§3.2 quota reclamation as policy", "",
            None if fast else be["revocations"] > 0),
        Row("replay", "borrow_load_collapse_x",
            placement.get("load_collapse_x", 0.0),
            "Fig. 16 NIC collapse inside the replay", "",
            None if fast else placement.get("load_collapse_x", 0.0) > 1.0),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "replay")


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
