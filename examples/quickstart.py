"""Quickstart: build a model from the registry, train it, checkpoint it,
restore it, and generate tokens — the whole public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.config import ParallelConfig, TrainConfig, get_smoke
from repro.core.ft.checkpoint import CheckpointManager
from repro.data import DataConfig, DataLoader, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serve import greedy_generate
from repro.sharding import make_rules
from repro.train import make_train_step
from repro.train.optimizer import adamw_init


def main() -> None:
    # 1. pick an architecture (any of the 12 registered ids; --smoke scale)
    cfg = get_smoke("h2o-danube-1.8b")
    mesh = make_host_mesh()
    parallel = ParallelConfig(remat="none", moe_impl="dense")
    model = Model(cfg, parallel, make_rules(mesh, parallel))
    params = model.init(jax.random.PRNGKey(0))

    # 2. train a few steps on the synthetic corpus
    tcfg = TrainConfig(global_batch=4, seq_len=64, learning_rate=1e-3,
                       warmup_steps=5, total_steps=30)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    loader = DataLoader(SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)))
    first = last = None
    for _ in range(30):
        _, batch = loader.next()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step(params, opt, batch)
        first = first if first is not None else float(metrics["loss"])
        last = float(metrics["loss"])
    print(f"loss {first:.3f} -> {last:.3f} over 30 steps")
    assert last < first

    # 3. async checkpoint + restore
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        stall = ckpt.save_async(30, (params, opt),
                                extra={"data_step": loader.step})
        ckpt.wait()
        (params2, _), extra = ckpt.restore(30, (params, opt))
        print(f"checkpoint stall {stall*1e3:.1f}ms, "
              f"restored data_step={extra['data_step']}")

    # 4. generate
    prompt = jnp.ones((2, 8), jnp.int32)
    out = greedy_generate(model, params2, prompt, n_tokens=8)
    print("generated:", out[0].tolist())


if __name__ == "__main__":
    main()
