"""Lower + compile one (arch x shape) cell on the production 16x16 mesh and
print its memory/cost/collective profile — the per-cell core of the
multi-pod dry-run, runnable standalone.

  PYTHONPATH=src python examples/dryrun_one_cell.py [arch] [shape]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import sys


def main() -> None:
    from repro.launch.dryrun import lower_cell
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES
    from repro.utils import human_bytes

    arch = sys.argv[1] if len(sys.argv) > 1 else "smollm-360m"
    shape = SHAPES[sys.argv[2] if len(sys.argv) > 2 else "train_4k"]
    mesh = make_production_mesh()
    print(f"lowering {arch} x {shape.name} on mesh {dict(mesh.shape)} ...")
    lowered = lower_cell(arch, shape, mesh)
    compiled = lowered.compile()
    a = analyze(compiled)
    mem, cost, coll = a["memory"], a["cost"], a["collectives"]
    print(f"  args/device : {human_bytes(mem.get('argument_size_in_bytes', 0))}")
    print(f"  temp/device : {human_bytes(mem.get('temp_size_in_bytes', 0))}")
    print(f"  HLO flops   : {cost.get('flops', float('nan')):.3e} "
          f"(scan bodies counted once; see launch/calibrate.py)")
    print(f"  collectives : {coll['counts']}")
    print(f"  coll bytes  : {human_bytes(coll['total_bytes_per_device'])}/device")


if __name__ == "__main__":
    main()
