"""§6.2 end-to-end: decoupled evaluation scheduling.

Part 1 — the calibrated cluster simulator reproduces the paper's makespan
reductions (1.3x on 1 node, 1.8x on 4 nodes) on the 63-dataset suite.
Part 2 — a *real* threaded mini-evaluation (actual JAX inference, throttled
remote weight loading, subprocess-style metric jobs) shows the same effect
in wall-clock time on this machine.

  PYTHONPATH=src python examples/decoupled_eval.py [--fast]

``--fast`` (used by the CI examples-smoke job) shrinks the threaded part to
a tiny model and suite so the walkthrough finishes in seconds.
"""
import argparse

import jax

from repro.config import get_smoke
from repro.core.evalsched import (ClusterSpec, schedule_baseline,
                                  schedule_decoupled, standard_suite)
from repro.core.evalsched.runner import (RemoteStore, make_suite,
                                         run_baseline, run_decoupled)
from repro.models import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small-scale knobs for CI smoke runs")
    args = ap.parse_args()

    print("=== simulated 63-dataset / 7B evaluation (paper Fig. 16) ===")
    suite = standard_suite(63)
    for nodes in (1, 4):
        spec = ClusterSpec(n_nodes=nodes)
        b = schedule_baseline(suite, spec)
        d = schedule_decoupled(suite, spec)
        print(f"  {nodes} node(s): baseline {b.makespan:5.1f} min "
              f"(gpu util {b.gpu_utilization:.0%})  decoupled "
              f"{d.makespan:5.1f} min (util {d.gpu_utilization:.0%})  "
              f"speedup {b.makespan / d.makespan:.2f}x")

    print("\n=== real threaded mini-evaluation on this machine ===")
    if args.fast:
        from repro.config import AttentionConfig, ModelConfig
        cfg = ModelConfig(
            name="smoke", num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            max_seq_len=64, vocab_pad_multiple=64,
            attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                      head_dim=16))
        n_datasets, bandwidth = 6, 16.0
    else:
        cfg = get_smoke("internlm-7b")
        n_datasets, bandwidth = 10, 4.0
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = RemoteStore(params, bandwidth_mbps=bandwidth)
    mini = make_suite(model, n_datasets=n_datasets, heavy_tail=0.6)
    try:
        base = run_baseline(model, store, mini, n_workers=2,
                            warm_params=params)
        dec = run_decoupled(model, store, mini, n_workers=2,
                            warm_params=params)
    finally:
        store.close()
    print(f"  baseline : {base.makespan_s:5.2f}s "
          f"(worker time: load {base.per_stage['load']:.2f}s, "
          f"infer {base.per_stage['infer']:.2f}s, "
          f"metric-held {base.per_stage['metric']:.2f}s)")
    print(f"  decoupled: {dec.makespan_s:5.2f}s "
          f"(one precursor load {dec.per_stage['load']:.2f}s, "
          f"metrics on CPU pool)")
    print(f"  speedup  : {base.makespan_s / dec.makespan_s:.2f}x")


if __name__ == "__main__":
    main()
