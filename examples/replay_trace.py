"""§3.2 + §5 end-to-end: failure-aware replay of the 6-month Kalos trace.

Walkthrough of the replay subsystem (``repro.cluster.replay``), the first
piece that exercises scheduling and fault tolerance in one scenario:

  1. generate the synthetic Acme job population (``workload.generate_jobs``);
  2. replay it through the ``ReservationScheduler`` *without* failures —
     this is exactly ``simulate_queue`` (the two share one engine);
  3. replay it again with the §5 interruption taxonomy injected
     (hardware / infra / preemption, per-jtype incidence): running jobs are
     interrupted, hardware faults run the §6.1 two-round detection sweep
     and cordon the node, progress rolls back to the last periodic
     checkpoint, and the job requeues with its remaining work;
  4. compare the two worlds: extra queueing, restart counts, lost GPU
     hours by class and type (the paper's Figs. 13-14 / Table 2 analogues);
  5. optionally flip on the greedy backfill policy to see how much of the
     eval delay is pure head-of-line blocking.

  PYTHONPATH=src python examples/replay_trace.py [--jobs N] [--backfill]
"""
import argparse
import time

import numpy as np

from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           generate_jobs, replay_trace)


def _queue_medians(jobs) -> dict:
    out = {}
    for t in sorted({j.jtype for j in jobs}):
        waits = [j.queue_min for j in jobs
                 if j.jtype == t and np.isfinite(j.queue_min)]
        out[t] = float(np.median(waits)) if waits else 0.0
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=100_000,
                    help="synthetic trace size (default 100k)")
    ap.add_argument("--backfill", action="store_true",
                    help="also replay with the greedy backfill policy")
    ap.add_argument("--rate-scale", type=float, default=2.0,
                    help="multiplier on the §5 incidence rates")
    args = ap.parse_args()

    print(f"=== generating {args.jobs} Kalos jobs ===")
    jobs = generate_jobs(KALOS, seed=0, n_jobs=args.jobs)

    print("\n=== world 1: no failures (pure §3.2 queue replay) ===")
    t0 = time.perf_counter()
    replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                 config=ReplayConfig())
    print(f"replayed in {time.perf_counter() - t0:.1f}s")
    clean_medians = _queue_medians(jobs)
    for t, m in sorted(clean_medians.items(), key=lambda kv: -kv[1]):
        print(f"  queue median {t:12s} {m:7.2f} min")

    print("\n=== world 2: §5 failure taxonomy injected ===")
    t0 = time.perf_counter()
    res = replay_trace(
        jobs, KALOS.n_gpus, reserved_frac=0.97,
        config=ReplayConfig(
            injector=FailureInjector(seed=1, rate_scale=args.rate_scale)))
    print(f"replayed in {time.perf_counter() - t0:.1f}s "
          f"({res.events_processed} events)")
    s = res.summary()
    print(f"  restarts: {s['total_restarts']}  "
          f"(killed after max restarts: {s['killed_jobs']})")
    print(f"  lost GPU time: {s['total_lost_gpu_hours']:.0f} GPU-hours")
    for name, v in s["lost_gpu_hours_by_class"].items():
        print(f"    {name:10s} {v['failures']:4d} failures  "
              f"{v['gpu_hours']:9.1f} GPUh lost  "
              f"{v['restart_overhead_min']:7.0f} min restart overhead")
    print(f"  cordons: {s['cordon_events']} nodes "
          f"({s['detection_probes']} two-round detection probes)")
    print("  extra queueing vs clean world (requeue waits included):")
    for t, v in s["queue_delay_quantiles"].items():
        extra = [j.requeue_wait_min for j in jobs if j.jtype == t]
        print(f"    {t:12s} p50 {v['p50_min']:7.2f}  p99 {v['p99_min']:9.2f} "
              f"min; mean requeue wait {np.mean(extra):6.2f} min")

    if args.backfill:
        print("\n=== world 3: greedy backfill instead of head-of-line ===")
        replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                     config=ReplayConfig(backfill=True))
        for t, m in sorted(_queue_medians(jobs).items(), key=lambda kv: -kv[1]):
            d = m - clean_medians[t]
            print(f"  queue median {t:12s} {m:7.2f} min ({d:+.2f} vs FIFO)")


if __name__ == "__main__":
    main()
