"""§3.2 + §5 + §6 end-to-end: diagnosis-in-the-loop replay of the Kalos trace.

Walkthrough of the replay subsystem (``repro.cluster.replay``), the piece
that exercises scheduling and fault tolerance in one scenario:

  1. generate the synthetic Acme job population (``workload.generate_jobs``);
  2. replay it through the ``ReservationScheduler`` *without* failures —
     this is exactly ``simulate_queue`` (the two share one engine);
  3. replay it again with the §5 interruption taxonomy injected AND the
     §6.1 diagnosis loop closed: every injected failure synthesizes its log
     snippet (``failures.synthesize_failure_log``), the ``core/ft``
     pipeline (LogCompressor → rules → Failure Agent) diagnoses it, and the
     verdict picks the recovery policy —

       hardware  -> cordon + requeue, or *elastic shrink* with --elastic:
                    drop the failed node, keep running narrower with the
                    remaining runtime stretched, regrow at the repair;
       transient -> in-place restart (keep the allocation, pay overhead);
       user      -> requeue for a human to fix;

  4. compare the two worlds: extra queueing, restart counts, lost GPU
     hours by class/type/policy, per-verdict diagnosis breakdowns (the
     paper's Figs. 13-14 / Table 2 analogues);
  5. optionally flip on a backfill policy to see how much of the eval
     delay is pure head-of-line blocking: ``--backfill greedy`` may delay
     the queue head, ``--backfill easy`` (conservative) never does;
  6. with ``--borrow``, attach the elastic capacity pool's §6.2 side: a
     ``TrialBorrower`` leases idle-fragment and shrunken-job GPUs from the
     replay free pool for decomposed eval shards, preempted back (paying
     the decomposed-trial restart cost) whenever dispatch or an elastic
     job's opportunistic regrowth wants the capacity; the run then prints
     the pool ledger — borrowed GPU-hours, lease/preemption counts,
     regrowth events and the blocked-head delay tail.

  7. with ``--placement``, every lease becomes *node-local*: a NodeLedger
     mirrors the capacity movements onto SimulatedFleet nodes, borrowed
     shards land on concrete nodes and their model loads share that
     node's 25 Gb/s storage NIC — the Fig. 16 load collapse, printed from
     ``summary()["placement"]``;
  8. with ``--best-effort FRAC``, that share of eligible jobs runs as
     *checkpointed best-effort* on revocable leases over idle capacity
     (including the pretraining reservation): the §3.2 quota-reclamation
     preemption as a scheduling policy — revocations roll the job back to
     its last checkpoint and requeue it.

  PYTHONPATH=src python examples/replay_trace.py \
      [--jobs N] [--elastic] [--borrow] [--placement] \
      [--best-effort FRAC] [--backfill {greedy,easy}]
"""
import argparse
import time

import numpy as np

from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           generate_jobs, recovery_stats, replay_trace)
from repro.core.evalsched import STORAGE_SPEC, TrialBorrower


def _queue_medians(jobs) -> dict:
    out = {}
    for t in sorted({j.jtype for j in jobs}):
        waits = [j.queue_min for j in jobs
                 if j.jtype == t and np.isfinite(j.queue_min)]
        out[t] = float(np.median(waits)) if waits else 0.0
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=100_000,
                    help="synthetic trace size (default 100k)")
    ap.add_argument("--elastic", action="store_true",
                    help="let hardware-verdict jobs shrink elastically "
                         "instead of requeueing")
    ap.add_argument("--borrow", action="store_true",
                    help="lease free-pool GPUs to decomposed eval trials "
                         "(the §6.1 x §6.2 elastic capacity pool)")
    ap.add_argument("--placement", action="store_true",
                    help="node-local leases: borrowed shards land on "
                         "concrete nodes and share the node storage NIC")
    ap.add_argument("--best-effort", type=float, default=0.0,
                    metavar="FRAC",
                    help="run FRAC of eligible jobs as checkpointed "
                         "best-effort on revocable leases")
    ap.add_argument("--backfill", choices=["greedy", "easy"], default=None,
                    help="also replay with a backfill policy")
    ap.add_argument("--rate-scale", type=float, default=2.0,
                    help="multiplier on the §5 incidence rates")
    args = ap.parse_args()

    print(f"=== generating {args.jobs} Kalos jobs ===")
    jobs = generate_jobs(KALOS, seed=0, n_jobs=args.jobs,
                         best_effort_frac=args.best_effort)

    print("\n=== world 1: no failures (pure §3.2 queue replay) ===")
    t0 = time.perf_counter()
    replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                 config=ReplayConfig())
    print(f"replayed in {time.perf_counter() - t0:.1f}s")
    clean_medians = _queue_medians(jobs)
    for t, m in sorted(clean_medians.items(), key=lambda kv: -kv[1]):
        print(f"  queue median {t:12s} {m:7.2f} min")

    print("\n=== world 2: §5 failures + §6.1 diagnosis-in-the-loop ===")
    spec = STORAGE_SPEC if args.placement else None
    borrower = (TrialBorrower.from_suite(63, repeat=20, spec=spec)
                if args.borrow else None)
    t0 = time.perf_counter()
    res = replay_trace(
        jobs, KALOS.n_gpus, reserved_frac=0.97,
        config=ReplayConfig(
            injector=FailureInjector(seed=1, rate_scale=args.rate_scale),
            diagnose=True, elastic=args.elastic, borrower=borrower,
            placement=args.placement,
            reshard_cost_min=1.0 if args.elastic else 0.0))
    print(f"replayed in {time.perf_counter() - t0:.1f}s "
          f"({res.events_processed} events)")
    s = res.summary()
    print(f"  restarts: {s['total_restarts']}  "
          f"(killed after max restarts: {s['killed_jobs']})")
    print(f"  lost GPU time: {s['total_lost_gpu_hours']:.0f} GPU-hours")
    for name, v in s["lost_gpu_hours_by_class"].items():
        print(f"    {name:10s} {v['failures']:4d} failures  "
              f"{v['gpu_hours']:9.1f} GPUh lost  "
              f"{v['restart_overhead_min']:7.0f} min restart overhead")
    print(f"  cordons: {s['cordon_events']} nodes "
          f"({s['detection_probes']} two-round detection probes)")

    rec = recovery_stats(res)
    print("  diagnosis verdicts per injected class "
          f"({rec['incidents']} incidents, "
          f"{res.diagnosis_pipeline_runs} pipeline runs):")
    for cls_name, verdicts in rec["diagnosis_verdicts"].items():
        mix = "  ".join(f"{v}={d['count']} ({d['frac']:.0%})"
                        for v, d in verdicts.items())
        print(f"    {cls_name:10s} -> {mix}")
    if rec["hardware_verdict_recall"] is not None:
        print(f"  hardware-verdict recall: "
              f"{rec['hardware_verdict_recall']:.1%} "
              f"(paper target: correctly cordon real node faults)")
    print("  recovery policies the verdicts picked:")
    for p, d in rec["policies"].items():
        print(f"    {p:10s} {d['count']:5d} ({d['frac']:5.1%})  "
              f"{d['gpu_hours_lost']:9.1f} GPUh lost  "
              f"{d['restart_overhead_min']:7.0f} min overhead")
    if args.elastic:
        pr = s["pool"]["regrowth"]
        print(f"  elastic: {pr['shrinks']} shrinks; regrowth "
              f"{pr['pool_regrows']} from the free pool + "
              f"{pr['repair_regrows']} at the lender's repair "
              f"({pr['pool_regrown_gpus']} GPUs reclaimed early, "
              f"{pr['reshard_stall_min']:.0f} min re-shard stall paid)")
    if args.best_effort > 0:
        be = s["pool"]["best_effort"]
        print(f"  best-effort tier: {be['jobs']} checkpointed jobs, "
              f"{be['lease_starts']} lease starts, "
              f"{be['revocations']} quota-reclamation revocations "
              f"({be['lost_gpu_hours']:.1f} GPUh rolled back)")
    if args.placement:
        p = s["placement"]
        print(f"  placement: {p['n_nodes']} nodes x {p['node_gpus']} GPUs, "
              f"{p['cordoned_nodes']} cordoned at drain")
        if "load_collapse_x" in p:
            print(f"    borrowed-load NIC collapse: up to "
                  f"{p['max_load_concurrency']} loads/node, slowest load "
                  f"{p['load_collapse_x']:.2f}x the solo load (Fig. 16)")
    if args.borrow:
        b = s["pool"]["borrow"]
        hd = s["head_delay"]
        print("  capacity pool (free-GPU ledger):")
        print(f"    trials borrowed {b['borrowed_gpu_hours']:.1f} GPUh over "
              f"{b['leases']} leases ({b['preemptions']} preempted back, "
              f"{b['restart_overhead_min']:.0f} min restart cost)")
        print(f"    {b['shards_completed']} eval shards finished, "
              f"{b['shards_pending']} pending at drain; "
              f"idle-capacity share used "
              f"{s['pool']['borrow_utilization']:.2e}")
        print(f"    blocked-head delay p50/p95/p99 = "
              f"{hd['p50_min']:.2f}/{hd['p95_min']:.2f}/"
              f"{hd['p99_min']:.2f} min over {hd['n']} head episodes")
    print("  extra queueing vs clean world (requeue waits included):")
    for t, v in s["queue_delay_quantiles"].items():
        extra = [j.requeue_wait_min for j in jobs if j.jtype == t]
        print(f"    {t:12s} p50 {v['p50_min']:7.2f}  p99 {v['p99_min']:9.2f} "
              f"min; mean requeue wait {np.mean(extra):6.2f} min")

    if args.backfill:
        print(f"\n=== world 3: {args.backfill} backfill instead of "
              f"head-of-line ===")
        replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                     config=ReplayConfig(backfill=args.backfill))
        for t, m in sorted(_queue_medians(jobs).items(), key=lambda kv: -kv[1]):
            d = m - clean_medians[t]
            print(f"  queue median {t:12s} {m:7.2f} min ({d:+.2f} vs FIFO)")


if __name__ == "__main__":
    main()
