"""§3 end-to-end: generate the 6-month Kalos trace, replay it through the
reservation scheduler, and print the paper's characterization findings.

  PYTHONPATH=src python examples/characterize_cluster.py
"""
from repro.cluster import KALOS, generate_jobs, simulate_queue, trace_summary

HORIZON = 6 * 30 * 24 * 60.0


def main() -> None:
    jobs = generate_jobs(KALOS, seed=0)
    jobs = simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.97)
    s = trace_summary(jobs, KALOS.n_gpus, HORIZON)

    print(f"=== {KALOS.name}: {s['n_jobs']} GPU jobs over 6 months "
          f"({KALOS.n_gpus} GPUs) ===\n")
    d = s["duration"]
    print(f"job duration: median {d['median_min']:.1f} min, "
          f"mean {d['mean_min']:.1f} min, "
          f">{{1 day}}: {d['frac_over_1day']:.1%}   (paper Fig. 2a: ~2 min)")
    print("\nworkload mix (paper Fig. 4):")
    for t, v in sorted(s["type_shares"].items(),
                       key=lambda kv: -kv[1]["count_frac"]):
        print(f"  {t:12s} {v['count_frac']:6.1%} of jobs   "
              f"{v['gputime_frac']:6.1%} of GPU time")
    dm = s["demand"]
    print(f"\nGPU demand (paper Fig. 3/5): median by type "
          f"{dm['median_by_type']}; jobs >=256 GPUs hold "
          f"{dm['gputime_frac_ge256']:.1%} of GPU time")
    print("\nqueueing delay (paper Fig. 6 — note the inversion):")
    for t, v in sorted(s["queue"].items(),
                       key=lambda kv: -kv[1]["median_min"]):
        print(f"  {t:12s} median {v['median_min']:6.2f} min   "
              f"mean {v['mean_min']:6.2f} min")
    print("\nfinal statuses (paper Fig. 17):")
    for t, v in s["status"].items():
        print(f"  {t:10s} {v['count_frac']:6.1%} of jobs   "
              f"{v['gputime_frac']:6.1%} of GPU time")


if __name__ == "__main__":
    main()
