"""Serving-cluster replay end-to-end: the inference-shaped counterpart of
``examples/replay_trace.py`` (§6.2 decoupled-eval motivation, north-star
"millions of users" serving scale).

Walkthrough of ``repro.cluster.serve_replay``:

  1. generate a diurnal + bursty request trace
     (``workload.generate_requests`` — lognormal prompt/output lengths,
     sine-of-day arrival thinning, a slice of traffic re-homed onto burst
     centers);
  2. replay it through a disaggregated serving fleet: ``--prefill``
     instances run prompt passes (TTFT = arrival -> first token, queueing
     included), ``--decode`` instances run continuous batching — a shared
     per-slot progress clock prices every resident's next token at the
     occupancy-dependent step time from the cost model's decode cell;
  3. print the serving scorecard: p50/p95/p99 TTFT and TPOT, SLO
     attainment against the config targets, batch occupancy, and the
     paged-KV pressure ledger (evictions, recomputed prefill tokens);
  4. with ``--kv-pages`` small enough, watch the LIFO eviction +
     recompute loop kick in: evicted requests keep their generated
     tokens but must re-prefill ``prompt + decoded`` through the
     prefill fleet before decoding resumes — every evicted KV token
     shows up again as a recomputed prefill token, a conservation law
     the test suite pins.

Rates come from the committed prefill/decode dry-run cells when present
(``CostModel.load``) and the deterministic analytic roofline otherwise —
pass ``--analytic`` to force the hermetic path CI uses.

With ``--inject``, the §5 hardware/infra taxonomy strikes serving
instances (scaled by ``--rate-scale`` so a short demo window still sees
incidents): each failure is diagnosed from a synthesized serving log,
the verdict picks cordon-and-respawn vs in-place restart, killed
requests retry through the prefill fleet, and the scorecard grows a
fault section — retries/drops/shed, degraded minutes, and per-class SLO
violation attribution — plus the extended conservation law
``evicted + killed == recomputed``.

  PYTHONPATH=src python examples/serve_trace.py \
      [--requests N] [--horizon MIN] [--arch A] [--analytic] \
      [--prefill N] [--decode N] [--kv-pages N] [--max-batch N] \
      [--inject] [--rate-scale X]
"""
import argparse
import time

from repro.cluster import (SERVING_TAXONOMY, FailureInjector,
                           ServeReplayConfig, generate_requests,
                           replay_requests)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100_000,
                    help="synthetic trace size (default 100k)")
    ap.add_argument("--horizon", type=float, default=144.0,
                    help="arrival window in minutes (default 144, i.e. "
                         "100k requests at the 1M/day Seren rate)")
    ap.add_argument("--arch", default="internlm-7b")
    ap.add_argument("--analytic", action="store_true",
                    help="force the hermetic analytic cost model "
                         "(no dryrun artifacts read)")
    ap.add_argument("--prefill", type=int, default=4,
                    help="prefill instances (8 GPUs each)")
    ap.add_argument("--decode", type=int, default=16,
                    help="decode instances (8 GPUs each)")
    ap.add_argument("--kv-pages", type=int, default=4096,
                    help="KV pages per decode instance (16 tokens/page); "
                         "try 1024 to force eviction churn")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="continuous-batching occupancy cap")
    ap.add_argument("--inject", action="store_true",
                    help="inject the §5 hardware/infra taxonomy into the "
                         "fleet (diagnosis-driven recovery + graceful "
                         "degradation)")
    ap.add_argument("--rate-scale", type=float, default=600.0,
                    help="failure-rate multiplier for --inject (datacenter "
                         "per-GPU-hour hazards are too rare for a "
                         "minutes-long demo window)")
    args = ap.parse_args()

    print(f"=== generating {args.requests} requests over "
          f"{args.horizon:.0f} min (diurnal + bursty) ===")
    reqs = generate_requests(args.requests, seed=0,
                             horizon_min=args.horizon)
    n_prompt = sum(r.prompt_tokens for r in reqs)
    n_out = sum(r.out_tokens for r in reqs)
    print(f"  {n_prompt / 1e6:.1f}M prompt tokens, "
          f"{n_out / 1e6:.1f}M output tokens")

    cm = None
    if args.analytic:
        from repro.launch.cost_model import CostModel
        cm = CostModel.analytic((args.arch,))
    inj = None
    if args.inject:
        inj = FailureInjector(SERVING_TAXONOMY, seed=1,
                              rate_scale=args.rate_scale)
    cfg = ServeReplayConfig(arch=args.arch, cost_model=cm,
                            n_prefill=args.prefill, n_decode=args.decode,
                            kv_pages=args.kv_pages,
                            max_batch=args.max_batch, injector=inj)

    print(f"\n=== replaying through {args.prefill} prefill + "
          f"{args.decode} decode instances ({args.arch}"
          f"{', faults injected' if inj else ''}) ===")
    t0 = time.perf_counter()
    res = replay_requests(reqs, cfg)
    wall = time.perf_counter() - t0
    s = res.summary()
    print(f"replayed in {wall:.1f}s ({s['events_processed']} events); "
          f"rates: {s['cost_model']['source']} "
          f"(prefill {s['cost_model']['prefill_tok_s']:.0f} tok/s, "
          f"decode {s['cost_model']['decode_fixed_ms']:.0f} ms "
          f"+ {s['cost_model']['decode_per_seq_ms']:.2f} ms/seq)")

    t, p = s["ttft"], s["tpot"]
    print(f"  TTFT p50/p95/p99 = {t['p50_s']:.2f}/{t['p95_s']:.2f}/"
          f"{t['p99_s']:.2f} s (mean {t['mean_s']:.2f})")
    print(f"  TPOT p50/p95/p99 = {p['p50_ms']:.0f}/{p['p95_ms']:.0f}/"
          f"{p['p99_ms']:.0f} ms")
    slo = s["slo"]
    print(f"  SLO attainment: TTFT<={slo['ttft_target_s']:.0f}s "
          f"{slo['ttft_attainment']:.1%}, "
          f"TPOT<={slo['tpot_target_ms']:.0f}ms "
          f"{slo['tpot_attainment']:.1%}, "
          f"joint {slo['joint_attainment']:.1%}")
    b = s["batch"]
    print(f"  decode occupancy: mean {b['mean_occupancy']:.1f} / "
          f"peak {b['peak_occupancy']} (cap {b['max_batch']}); "
          f"mean admit wait {b['admit_wait_mean_min'] * 60:.2f} s")
    kv = s["kv"]
    print(f"  KV: peak {kv['peak_pages']:.0f}/{kv['pages_per_instance']} "
          f"pages ({kv['peak_pages_frac']:.0%}); "
          f"{kv['evictions']} evictions, "
          f"{kv['evicted_tokens']} tokens evicted == "
          f"{kv['recompute_prefill_tokens']} recomputed (conservation)")
    th = s["throughput"]
    print(f"  throughput: {th['decoded_tok_per_s']:.0f} decoded tok/s, "
          f"{th['requests_per_min']:.0f} req/min; "
          f"{s['completed']} completed, {s['rejected']} rejected")
    fl = s["fleet"]
    print(f"  fleet: {fl['n_prefill']}+{fl['n_decode']} instances x "
          f"{fl['gpus_per_instance']} GPUs on {fl['nodes_used']} nodes "
          f"(of {fl['total_gpus']} GPUs)")

    if "faults" in s:
        f = s["faults"]
        print(f"  faults: {f['injected']} injected -> "
              f"{f['respawns']} respawns + {f['inplace_restarts']} "
              f"in-place restarts ({f['cordoned_nodes']} nodes cordoned); "
              f"degraded {f['degraded_min']:.1f} min")
        print(f"    {f['retries']} retries, {f['drops']} drops, "
              f"{f['shed']} shed, {f['hol_skips']} HOL skips; "
              f"{f['killed_tokens']} tokens killed "
              f"(evicted + killed == recomputed: "
              f"{kv['evicted_tokens']} + {f['killed_tokens']} == "
              f"{kv['recompute_prefill_tokens']})")
        for name, c in f["by_class"].items():
            print(f"    {name}: {c['failures']} failures "
                  f"({c['prefill']} prefill / {c['decode']} decode), "
                  f"verdicts {c['verdicts']}, "
                  f"SLO viol TTFT {c['slo_ttft_violations']} / "
                  f"TPOT {c['slo_tpot_violations']}, "
                  f"down {c['downtime_min']:.0f} min")


if __name__ == "__main__":
    main()
