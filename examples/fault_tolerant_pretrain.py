"""§6.1 end-to-end: fault-tolerant pretraining on a real JAX training loop.

Injects two Table-3 infrastructure failures and a loss spike into a smoke-
scale smollm run. The supervisor diagnoses each failure from its synthetic
runtime log (rule+agent pipeline), runs the two-round allgather sweep to
cordon the faulty node, restarts from the freshest (in-RAM) checkpoint, and
on the spike rolls back to an earlier checkpoint while skipping the
poisoned batches. Training completes unattended.

  PYTHONPATH=src python examples/fault_tolerant_pretrain.py
"""
import tempfile

from repro.config import ParallelConfig, TrainConfig, get_smoke
from repro.core.ft.checkpoint import CheckpointManager
from repro.core.ft.detection import SimulatedFleet
from repro.core.ft.diagnosis import FailureDiagnosisSystem
from repro.core.ft.events import BY_NAME
from repro.core.ft.supervisor import Supervisor
from repro.launch.mesh import make_host_mesh
from repro.launch.train import Trainer
from repro.models import Model
from repro.sharding import make_rules

STEPS = 90


def main() -> None:
    cfg = get_smoke("smollm-360m")
    mesh = make_host_mesh()
    parallel = ParallelConfig(remat="none", moe_impl="dense")
    tcfg = TrainConfig(global_batch=4, seq_len=64, total_steps=STEPS,
                       warmup_steps=5, learning_rate=1e-3)
    model = Model(cfg, parallel, make_rules(mesh, parallel))

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=4)
        trainer = Trainer(
            model, tcfg, mesh, parallel, ckpt, total_steps=STEPS,
            ckpt_every=10, log_every=15,
            fault_schedule={30: BY_NAME["NVLinkError"],
                            60: BY_NAME["ConnectionError"]},
            spike_schedule={45 + i: 6.0 for i in range(6)})
        fleet = SimulatedFleet(8)
        supervisor = Supervisor(ckpt, FailureDiagnosisSystem(), fleet)
        report = supervisor.run(trainer.job)
        ckpt.wait()

    print("\n=== supervisor report ===")
    for e in report.events:
        if e.kind == "failure":
            print(f"  step {e.step}: {e.diagnosis.failure} "
                  f"({e.diagnosis.source}, truth={e.truth}) "
                  f"-> resumed from {e.resumed_from}"
                  + (f", cordoned {e.detection.faulty} in "
                     f"{e.detection.probes} probes" if e.detection else ""))
        elif e.kind == "spike":
            print(f"  step {e.step}: loss spike -> rollback to "
                  f"{e.resumed_from}, data skipped")
    losses = [l for _, l in trainer.history]
    print(f"completed={report.completed} attempts={report.attempts} "
          f"auto={report.auto_recoveries} manual={report.manual_interventions}")
    print(f"lost steps: {report.lost_steps}; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")
    print(f"cordoned nodes: {sorted(fleet.cordoned)}")
    assert report.completed and report.manual_interventions == 0


if __name__ == "__main__":
    main()
